"""Typed live-metrics substrate: counters, gauges, mergeable histograms.

The runtime's post-hoc observability (CCT attribution, Chrome traces)
answers "where did the time go" after a replay ends; this module is the
*live* half: instruments the arbiter/engine hot paths update in place,
cheap enough to leave on, and a streaming replay can serve every report
statistic from them without accumulating a record list (ROADMAP item 2's
million-event memory flatness).

Three instrument kinds, registered in a ``MetricsRegistry``:

* ``Counter`` -- monotone float accumulator (``inc``);
* ``Gauge``   -- last-write-wins level (``set``/``inc``/``dec``);
* ``Histogram`` -- constant-memory log-bucketed distribution.

**Histogram semantics.**  Positive observations land in geometric
buckets: value ``v`` maps to bucket ``floor(resolution * log2(v))``, so
each bucket spans a ``2**(1/resolution)`` growth factor (default
resolution 16 -> ~4.4% per bucket); values <= 0 land in a dedicated zero
bucket.  ``quantile(q)`` ranks observations exactly like
``ReplayReport``'s percentile indexing (0-based rank
``min(n-1, int(q*n))``) and returns the covering bucket's upper edge
clamped to the observed max, which yields the documented error bound:
for true rank value ``v``,

    ``v <= quantile(q) <= v * 2**(1/resolution)``

(up to one ulp of ``log2`` rounding at exact bucket edges).  ``merge``
adds integer bucket counts -- **exact, associative and commutative** --
so ``count``/``min``/``max`` and every quantile are invariant under any
merge tree (shard-then-merge equals observing centrally).  ``sum`` is
IEEE-754 addition: commutative-in-value but, like any float sum, only
associative to rounding; means derived from it carry ~1 ulp per merge.

**Exporters.**  ``to_prometheus_text`` emits the Prometheus text
exposition format (histograms as cumulative ``_bucket{le=...}`` series
plus ``_sum``/``_count``); ``to_json`` round-trips full fidelity
(``from_json``), which is what makes registries mergeable across
processes.  ``python -m repro.obs.metrics validate FILE...`` checks
either format (the CI metrics-smoke job runs it); ``merge`` folds JSON
exports into one registry.

The default handle is ``NULL_REGISTRY`` (``enabled=False``): call sites
follow the ``NullTracer`` discipline -- guard with one attribute load
and skip instrument updates entirely when disabled.
"""

from __future__ import annotations

import json
import math
import re
from typing import Any, Iterable, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NullRegistry",
    "validate_prometheus_text",
]

DEFAULT_RESOLUTION = 16  # buckets per octave: 2**(1/16) ~ 4.43% growth

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _check_name(name: str) -> None:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")


def _check_label(label: str) -> None:
    if not _LABEL_RE.match(label) or label == "le":
        raise ValueError(f"invalid label name {label!r}")


def _escape(value: str) -> str:
    return (
        value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    )


def _fmt(value: float) -> str:
    if math.isnan(value):
        return "NaN"
    if math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    return repr(float(value))


# -- instrument children ----------------------------------------------------


class _CounterValue:
    """One (label-set) counter cell: monotone float accumulator."""

    __slots__ = ("_value",)
    kind = "counter"

    def __init__(self) -> None:
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0: {amount}")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value


class _GaugeValue:
    """One (label-set) gauge cell: settable level."""

    __slots__ = ("_value",)
    kind = "gauge"

    def __init__(self) -> None:
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount

    @property
    def value(self) -> float:
        return self._value


class _HistogramValue:
    """One (label-set) histogram cell: log-bucketed counts.

    Memory is O(occupied buckets) -- bounded by the observed dynamic
    range times ``resolution`` (e.g. waits spanning 1us..1s at
    resolution 16 occupy <= 320 buckets), independent of observation
    count.  See the module docstring for merge/quantile semantics.
    """

    __slots__ = ("_resolution", "_buckets", "_zero", "_n", "_sum",
                 "_min", "_max")
    kind = "histogram"

    def __init__(self, resolution: int = DEFAULT_RESOLUTION) -> None:
        if resolution < 1:
            raise ValueError("resolution must be >= 1")
        self._resolution = resolution
        self._buckets: dict[int, int] = {}
        self._zero = 0  # observations <= 0.0
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    @property
    def resolution(self) -> int:
        return self._resolution

    @property
    def quantile_error(self) -> float:
        """Documented relative quantile error bound: the bucket growth
        factor minus one (``quantile(q)`` never exceeds the true rank
        value by more than this fraction, and never falls below it)."""
        return 2.0 ** (1.0 / self._resolution) - 1.0

    def observe(self, value: float) -> None:
        value = float(value)
        self._n += 1
        self._sum += value
        if value < self._min:
            self._min = value
        if value > self._max:
            self._max = value
        if value <= 0.0:
            self._zero += 1
            return
        i = math.floor(self._resolution * math.log2(value))
        self._buckets[i] = self._buckets.get(i, 0) + 1

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def min(self) -> float:
        return self._min if self._n else math.nan

    @property
    def max(self) -> float:
        return self._max if self._n else math.nan

    @property
    def mean(self) -> float:
        return self._sum / self._n if self._n else math.nan

    def quantile(self, q: float) -> float:
        """Rank-``min(n-1, int(q*n))`` estimate (see module docstring)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1]: {q}")
        if self._n == 0:
            return math.nan
        rank = min(self._n - 1, int(q * self._n))
        cum = self._zero
        if rank < cum:
            return 0.0
        for i in sorted(self._buckets):
            cum += self._buckets[i]
            if rank < cum:
                edge = 2.0 ** ((i + 1) / self._resolution)
                return min(edge, self._max)
        return self._max  # unreachable: bucket counts cover every rank

    def quantiles(self, qs: Iterable[float]) -> tuple[float, ...]:
        return tuple(self.quantile(q) for q in qs)

    def merge_from(self, other: "_HistogramValue") -> None:
        """Fold ``other`` in: integer bucket adds (exact), float sum."""
        if other._resolution != self._resolution:
            raise ValueError(
                f"cannot merge histograms with resolutions "
                f"{self._resolution} and {other._resolution}"
            )
        self._n += other._n
        self._sum += other._sum
        self._zero += other._zero
        for i, c in other._buckets.items():
            self._buckets[i] = self._buckets.get(i, 0) + c
        if other._min < self._min:
            self._min = other._min
        if other._max > self._max:
            self._max = other._max

    def merge(self, other: "_HistogramValue") -> "_HistogramValue":
        """Pure merge: a new cell holding both distributions."""
        out = _HistogramValue(self._resolution)
        out.merge_from(self)
        out.merge_from(other)
        return out


# -- metric families --------------------------------------------------------


class _Family:
    """A named metric with a fixed label schema; holds one cell per
    observed label-value tuple (the classic Prometheus family shape).
    Unlabeled metrics hold a single default cell and expose its methods
    directly."""

    _value_cls: type = _CounterValue

    def __init__(
        self,
        name: str,
        help: str = "",
        labelnames: Iterable[str] = (),
    ) -> None:
        _check_name(name)
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        for label in self.labelnames:
            _check_label(label)
        self._children: dict[tuple[str, ...], Any] = {}

    @property
    def kind(self) -> str:
        return self._value_cls.kind

    def _new_child(self):
        return self._value_cls()

    def labels(self, *values: Any, **by_name: Any):
        """The cell for one label-value tuple (created on first use)."""
        if by_name:
            if values:
                raise ValueError("pass labels positionally or by name")
            try:
                values = tuple(by_name[ln] for ln in self.labelnames)
            except KeyError as e:
                raise ValueError(f"missing label {e.args[0]!r}") from None
        key = tuple(str(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name} takes labels {self.labelnames}, got {key}"
            )
        child = self._children.get(key)
        if child is None:
            child = self._new_child()
            self._children[key] = child
        return child

    def collect(self) -> dict[tuple[str, ...], Any]:
        """Label tuple -> cell, sorted for stable export order."""
        return dict(sorted(self._children.items()))

    def _default(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} requires labels {self.labelnames}"
            )
        return self.labels()


class Counter(_Family):
    _value_cls = _CounterValue

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    @property
    def value(self) -> float:
        return self._default().value


class Gauge(_Family):
    _value_cls = _GaugeValue

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default().dec(amount)

    @property
    def value(self) -> float:
        return self._default().value


class Histogram(_Family):
    _value_cls = _HistogramValue

    def __init__(
        self,
        name: str,
        help: str = "",
        labelnames: Iterable[str] = (),
        *,
        resolution: int = DEFAULT_RESOLUTION,
    ) -> None:
        super().__init__(name, help, labelnames)
        self.resolution = resolution

    def _new_child(self) -> _HistogramValue:
        return _HistogramValue(self.resolution)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    def quantile(self, q: float) -> float:
        return self._default().quantile(q)

    @property
    def count(self) -> int:
        return self._default().count

    @property
    def sum(self) -> float:
        return self._default().sum

    def aggregate(self) -> _HistogramValue:
        """All label cells merged into one distribution (exact counts)."""
        out = _HistogramValue(self.resolution)
        for child in self._children.values():
            out.merge_from(child)
        return out


# -- registry ---------------------------------------------------------------


class MetricsRegistry:
    """Create-or-get instrument registry with text/JSON exporters.

    ``counter``/``gauge``/``histogram`` return the existing family when
    the name is already registered (validating that kind and label
    schema agree), so hot-path modules can declare their instruments
    independently against one shared registry.
    """

    enabled: bool = True

    def __init__(self) -> None:
        self._families: dict[str, _Family] = {}

    def _register(self, cls: type, name: str, help: str,
                  labelnames: Iterable[str], **kwargs) -> Any:
        fam = self._families.get(name)
        if fam is None:
            fam = cls(name, help, labelnames, **kwargs)
            self._families[name] = fam
            return fam
        labelnames = tuple(labelnames)
        if not isinstance(fam, cls) or fam.labelnames != labelnames:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}"
            )
        if kwargs.get("resolution", getattr(fam, "resolution", None)) != (
            getattr(fam, "resolution", None)
        ):
            raise ValueError(
                f"histogram {name!r} already registered with resolution "
                f"{fam.resolution}"
            )
        return fam

    def counter(
        self, name: str, help: str = "", labelnames: Iterable[str] = ()
    ) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(
        self, name: str, help: str = "", labelnames: Iterable[str] = ()
    ) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(
        self,
        name: str,
        help: str = "",
        labelnames: Iterable[str] = (),
        *,
        resolution: int = DEFAULT_RESOLUTION,
    ) -> Histogram:
        return self._register(
            Histogram, name, help, labelnames, resolution=resolution
        )

    def get(self, name: str) -> _Family | None:
        return self._families.get(name)

    def families(self) -> dict[str, _Family]:
        return dict(sorted(self._families.items()))

    # -- merge --------------------------------------------------------------
    def merge_from(self, other: "MetricsRegistry") -> None:
        """Fold ``other``'s families in (multi-shard rollup).

        Counters and gauges merge additively (a summed gauge reads as
        fleet total -- e.g. free planes across shards); histograms merge
        exactly per the bucket-count semantics.  Kind/label mismatches
        on a shared name raise.
        """
        for name, fam in other.families().items():
            if isinstance(fam, Histogram):
                mine = self.histogram(
                    name, fam.help, fam.labelnames,
                    resolution=fam.resolution,
                )
                for key, child in fam.collect().items():
                    mine.labels(*key).merge_from(child)
            elif isinstance(fam, Gauge):
                mine = self.gauge(name, fam.help, fam.labelnames)
                for key, child in fam.collect().items():
                    mine.labels(*key).inc(child.value)
            else:
                mine = self.counter(name, fam.help, fam.labelnames)
                for key, child in fam.collect().items():
                    mine.labels(*key).inc(child.value)

    # -- exporters ----------------------------------------------------------
    def _label_str(
        self, fam: _Family, key: tuple[str, ...], extra: str = ""
    ) -> str:
        parts = [
            f'{ln}="{_escape(v)}"' for ln, v in zip(fam.labelnames, key)
        ]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: list[str] = []
        for name, fam in self.families().items():
            if fam.help:
                lines.append(f"# HELP {name} {_escape(fam.help)}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in fam.collect().items():
                if isinstance(child, _HistogramValue):
                    cum = child._zero
                    lines.append(
                        f"{name}_bucket"
                        f"{self._label_str(fam, key, extra=_le(0.0))}"
                        f" {cum}"
                    )
                    for i in sorted(child._buckets):
                        cum += child._buckets[i]
                        edge = 2.0 ** ((i + 1) / child._resolution)
                        lines.append(
                            f"{name}_bucket"
                            f"{self._label_str(fam, key, extra=_le(edge))}"
                            f" {cum}"
                        )
                    lines.append(
                        f"{name}_bucket"
                        f"{self._label_str(fam, key, extra=_le(math.inf))}"
                        f" {child._n}"
                    )
                    lines.append(
                        f"{name}_sum{self._label_str(fam, key)}"
                        f" {_fmt(child._sum)}"
                    )
                    lines.append(
                        f"{name}_count{self._label_str(fam, key)}"
                        f" {child._n}"
                    )
                else:
                    lines.append(
                        f"{name}{self._label_str(fam, key)}"
                        f" {_fmt(child.value)}"
                    )
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict[str, Any]:
        """Full-fidelity export; ``from_json`` round-trips it."""
        metrics: list[dict[str, Any]] = []
        for name, fam in self.families().items():
            entry: dict[str, Any] = {
                "name": name,
                "kind": fam.kind,
                "help": fam.help,
                "labelnames": list(fam.labelnames),
                "samples": [],
            }
            if isinstance(fam, Histogram):
                entry["resolution"] = fam.resolution
            for key, child in fam.collect().items():
                labels = dict(zip(fam.labelnames, key))
                if isinstance(child, _HistogramValue):
                    entry["samples"].append(
                        {
                            "labels": labels,
                            "count": child._n,
                            "sum": child._sum,
                            "zero": child._zero,
                            "min": child._min if child._n else None,
                            "max": child._max if child._n else None,
                            "buckets": {
                                str(i): c
                                for i, c in sorted(child._buckets.items())
                            },
                        }
                    )
                else:
                    entry["samples"].append(
                        {"labels": labels, "value": child.value}
                    )
            metrics.append(entry)
        return {"version": 1, "metrics": metrics}

    @classmethod
    def from_json(cls, payload: Mapping[str, Any]) -> "MetricsRegistry":
        """Rebuild a registry from ``to_json`` output (validating it)."""
        if not isinstance(payload, Mapping) or "metrics" not in payload:
            raise ValueError("metrics payload must have a 'metrics' list")
        if payload.get("version") != 1:
            raise ValueError(
                f"unsupported metrics payload version "
                f"{payload.get('version')!r}"
            )
        reg = cls()
        for entry in payload["metrics"]:
            kind = entry.get("kind")
            name = entry.get("name", "")
            labelnames = tuple(entry.get("labelnames", ()))
            if kind == "histogram":
                fam = reg.histogram(
                    name,
                    entry.get("help", ""),
                    labelnames,
                    resolution=int(entry.get(
                        "resolution", DEFAULT_RESOLUTION
                    )),
                )
            elif kind == "gauge":
                fam = reg.gauge(name, entry.get("help", ""), labelnames)
            elif kind == "counter":
                fam = reg.counter(name, entry.get("help", ""), labelnames)
            else:
                raise ValueError(
                    f"metric {name!r} has unknown kind {kind!r}"
                )
            for sample in entry.get("samples", ()):
                labels = sample.get("labels", {})
                key = tuple(str(labels[ln]) for ln in labelnames)
                child = fam.labels(*key)
                if kind == "histogram":
                    child._n = int(sample["count"])
                    child._sum = float(sample["sum"])
                    child._zero = int(sample.get("zero", 0))
                    child._min = (
                        float(sample["min"])
                        if sample.get("min") is not None
                        else math.inf
                    )
                    child._max = (
                        float(sample["max"])
                        if sample.get("max") is not None
                        else -math.inf
                    )
                    buckets = {
                        int(i): int(c)
                        for i, c in sample.get("buckets", {}).items()
                    }
                    if any(c < 0 for c in buckets.values()):
                        raise ValueError(
                            f"histogram {name!r} has negative bucket"
                        )
                    if sum(buckets.values()) + child._zero != child._n:
                        raise ValueError(
                            f"histogram {name!r} bucket counts do not "
                            f"sum to count"
                        )
                    child._buckets = buckets
                elif kind == "gauge":
                    child.set(float(sample["value"]))
                else:
                    child.inc(float(sample["value"]))
        return reg


def _le(edge: float) -> str:
    return f'le="{_fmt(edge)}"'


class _NullInstrument:
    """Shared no-op cell: every mutator is a pass, ``labels`` returns
    itself, reads return empty values.  One instance serves every
    instrument the ``NullRegistry`` hands out."""

    enabled = False
    kind = "null"
    value = 0.0
    count = 0
    sum = 0.0
    resolution = DEFAULT_RESOLUTION

    def labels(self, *values: Any, **by_name: Any) -> "_NullInstrument":
        return self

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return math.nan

    def collect(self) -> dict:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry(MetricsRegistry):
    """Disabled registry: ``enabled=False`` and no-op instruments.

    The metrics analogue of ``NULL_TRACER``: hot paths hold one of
    these by default and guard every update with ``if metrics.enabled``,
    so the disabled cost is a single attribute load per site.
    """

    enabled = False

    def counter(self, name, help="", labelnames=()):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def gauge(self, name, help="", labelnames=()):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def histogram(self, name, help="", labelnames=(), *, resolution=DEFAULT_RESOLUTION):  # type: ignore[override]
        return _NULL_INSTRUMENT


NULL_REGISTRY = NullRegistry()


# -- Prometheus text validation ---------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)(?:\s+\d+)?$"
)
_LABEL_PAIR_RE = re.compile(
    r'\s*([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"\s*(?:,|$)'
)
_HIST_SUFFIX = ("_bucket", "_sum", "_count")


def _parse_value(raw: str) -> float:
    if raw == "+Inf":
        return math.inf
    if raw == "-Inf":
        return -math.inf
    if raw == "NaN":
        return math.nan
    return float(raw)  # raises ValueError on junk


def validate_prometheus_text(text: str) -> int:
    """Raise ``ValueError`` unless ``text`` is a well-formed exposition.

    Checks the structure CI relies on: every sample line parses, every
    sampled metric carries a ``# TYPE``, histogram ``_bucket`` series
    are cumulative and non-decreasing in ``le`` order, end at ``+Inf``,
    and agree with the family's ``_count``.  Returns the number of
    sample lines checked.
    """
    types: dict[str, str] = {}
    # (name, non-le labels) -> list of (le, cumulative count)
    buckets: dict[tuple[str, tuple], list[tuple[float, float]]] = {}
    counts: dict[tuple[str, tuple], float] = {}
    n_samples = 0
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4 or parts[3] not in (
                "counter", "gauge", "histogram", "summary", "untyped"
            ):
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # HELP / comments: free-form
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample: {line!r}")
        name = m.group("name")
        try:
            value = _parse_value(m.group("value"))
        except ValueError:
            raise ValueError(
                f"line {lineno}: bad sample value {m.group('value')!r}"
            ) from None
        labels: dict[str, str] = {}
        raw = m.group("labels")
        if raw:
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(raw):
                labels[pm.group(1)] = pm.group(2)
                consumed = pm.end()
            if consumed != len(raw):
                raise ValueError(
                    f"line {lineno}: malformed labels {raw!r}"
                )
        base = name
        for suffix in _HIST_SUFFIX:
            stem = name[: -len(suffix)] if name.endswith(suffix) else None
            if stem and types.get(stem) == "histogram":
                base = stem
                break
        if base not in types:
            raise ValueError(
                f"line {lineno}: sample {name!r} has no # TYPE"
            )
        n_samples += 1
        if types[base] == "histogram" and name == f"{base}_bucket":
            if "le" not in labels:
                raise ValueError(
                    f"line {lineno}: histogram bucket missing 'le'"
                )
            le = _parse_value(labels["le"])
            key = (
                base,
                tuple(sorted(
                    (k, v) for k, v in labels.items() if k != "le"
                )),
            )
            buckets.setdefault(key, []).append((le, value))
        elif types[base] == "histogram" and name == f"{base}_count":
            key = (base, tuple(sorted(labels.items())))
            counts[key] = value
    for (base, lkey), series in buckets.items():
        les = [le for le, _ in series]
        if les != sorted(les):
            raise ValueError(
                f"histogram {base!r}{dict(lkey)}: 'le' edges not sorted"
            )
        cums = [c for _, c in series]
        if any(b < a for a, b in zip(cums, cums[1:])):
            raise ValueError(
                f"histogram {base!r}{dict(lkey)}: cumulative bucket "
                f"counts decrease"
            )
        if not math.isinf(les[-1]):
            raise ValueError(
                f"histogram {base!r}{dict(lkey)}: missing +Inf bucket"
            )
        total = counts.get((base, lkey))
        if total is not None and total != cums[-1]:
            raise ValueError(
                f"histogram {base!r}{dict(lkey)}: _count {total} != "
                f"+Inf bucket {cums[-1]}"
            )
    return n_samples


# -- CLI --------------------------------------------------------------------


def _validate_file(path: str) -> str:
    if path.endswith(".json"):
        with open(path) as fh:
            reg = MetricsRegistry.from_json(json.load(fh))
        return f"{path}: valid metrics JSON ({len(reg.families())} metrics)"
    with open(path) as fh:
        n = validate_prometheus_text(fh.read())
    return f"{path}: valid Prometheus exposition ({n} samples)"


def main(argv: list[str] | None = None) -> int:
    """``python -m repro.obs.metrics {validate|merge} ...``."""
    import sys

    args = list(sys.argv[1:] if argv is None else argv)
    usage = (
        "usage: python -m repro.obs.metrics validate FILE...\n"
        "       python -m repro.obs.metrics merge OUT.json IN.json..."
    )
    if not args:
        print(usage)
        return 2
    cmd, rest = args[0], args[1:]
    if cmd == "validate":
        if not rest:
            print(usage)
            return 2
        for path in rest:
            try:
                print(_validate_file(path))
            except (ValueError, KeyError, json.JSONDecodeError) as e:
                print(f"{path}: INVALID: {e}")
                return 1
        return 0
    if cmd == "merge":
        if len(rest) < 2:
            print(usage)
            return 2
        out_path, in_paths = rest[0], rest[1:]
        merged = MetricsRegistry()
        for path in in_paths:
            with open(path) as fh:
                merged.merge_from(MetricsRegistry.from_json(json.load(fh)))
        with open(out_path, "w") as fh:
            json.dump(merged.to_json(), fh)
        print(
            f"merged {len(in_paths)} registries "
            f"({len(merged.families())} metrics) -> {out_path}"
        )
        return 0
    print(usage)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
