"""CCT attribution: where does the completion time go, per plane per step?

The paper's claim is about *time accounting* -- how much of the
reconfiguration latency is hidden by overlapping it with other planes'
transmissions.  A scalar CCT cannot show that.  This module decomposes
the CCT of any legal schedule into five per-plane components:

* ``t_xmit``         -- time spent transmitting direct (non-relay) traffic;
* ``t_bypass``       -- time spent carrying relay hops over installed
  configs (Topology Bypassing, DESIGN.md section 15);
* ``t_recfg_wait``   -- *exposed* reconfiguration time: the amount by which
  a reconfiguration delayed its plane's next transmission beyond the step
  barrier.  In CHAIN mode this is
  ``max(barrier, free + t_recfg) - max(barrier, free)`` (somewhere in
  ``[0, t_recfg]``); INDEPENDENT mode has no barrier to hide behind, so
  every reconfiguration is fully exposed.
* ``t_recfg_hidden`` -- the rest of ``t_recfg``: reconfiguration that ran
  while the step barrier would have stalled the plane anyway.  This is the
  paper's reconfiguration-communication overlap, measured.
* ``t_idle``         -- the closing term: per plane,
  ``cct - (xmit + bypass + wait + hidden)``.  Barrier stalls, ready-time
  offsets, and post-finish slack all land here.

The five components sum *bitwise* to the CCT on every backend: ``t_idle``
is defined as the exact floating-point complement (``closing_idle``
refines it below the ulp when ``cct - comp`` rounds), so conservation is
a construction invariant, not a tolerance statement.  What keeps the four
*measured* components honest is oracle parity: the array recurrence and
the object-walk ``attribute`` agree within ``repro.core.tolerances``
(property-tested in tests/test_obs.py).

The derived **overlap efficiency** -- hidden / (hidden + exposed)
reconfiguration time -- is the paper's headline as a single number in
``[0, 1]``; schedules with no reconfigurations at all report 1.0
(vacuously: there was nothing to expose).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedule import DependencyMode, Kind, Schedule

__all__ = [
    "Attribution",
    "attribute",
    "build_attribution",
    "closing_idle",
    "component_sum",
    "step_barriers",
]


def step_barriers(schedule: Schedule) -> tuple[float, ...]:
    """Barrier in force entering each step, in schedule-relative time.

    The running max of earlier steps' transmission-window ends (bypass
    hops included) -- exactly the recurrence's carried barrier; steps
    with no transmission activity inherit the previous barrier.  Shared
    by ``attribute`` and the arbiter's incremental per-job attribution
    (``CachedPlan.barriers``), so exposed-vs-hidden splits agree bitwise
    between the post-hoc and live paths.
    """
    n_steps = schedule.pattern.n_steps
    step_end = [-np.inf] * n_steps
    for a in schedule.activities:
        if a.kind is Kind.XMIT and a.end > step_end[a.step]:
            step_end[a.step] = a.end
    barriers = [0.0] * n_steps
    running = 0.0
    for i in range(n_steps):
        barriers[i] = running
        if step_end[i] > running:
            running = step_end[i]
    return tuple(barriers)


def component_sum(
    t_xmit: np.ndarray,
    t_bypass: np.ndarray,
    t_recfg_wait: np.ndarray,
    t_recfg_hidden: np.ndarray,
) -> np.ndarray:
    """The canonical per-plane component reduction (summed over steps).

    One fixed association order shared by every producer and consumer, so
    "components sum to the CCT" means the same float on every backend.
    """
    return (
        t_xmit.sum(axis=-2)
        + t_bypass.sum(axis=-2)
        + t_recfg_wait.sum(axis=-2)
        + t_recfg_hidden.sum(axis=-2)
    )


def closing_idle(
    cct: np.ndarray, comp: np.ndarray, plane_mask: np.ndarray
) -> np.ndarray:
    """Per-plane idle time such that ``comp + idle == cct`` *bitwise*.

    ``cct - comp`` is exact by Sterbenz's lemma whenever ``comp`` is
    within a factor of two of ``cct``; outside that range the subtraction
    can round, leaving ``comp + idle`` a ulp off.  The refinement loop
    folds that residual back into ``idle`` (each pass shrinks the error
    below the previous ulp; two passes suffice in practice, four is a
    safe bound).
    """
    cct_col = np.asarray(cct, dtype=np.float64)[..., None]
    comp = np.asarray(comp, dtype=np.float64)
    idle = np.where(plane_mask, cct_col - comp, 0.0)
    for _ in range(4):
        err = np.where(plane_mask, cct_col - (comp + idle), 0.0)
        if not err.any():
            break
        idle = idle + err
    return idle


@dataclasses.dataclass(frozen=True)
class Attribution:
    """Per-(step, plane) CCT decomposition.

    Arrays carry an optional leading batch dimension: ``attribute``
    returns ``(S, P)`` components for one schedule, the batched engine
    returns ``(B, S, P)`` (padded steps/planes hold exact zeros; use the
    masks to trim).  All reductions below work for either shape.
    """

    t_xmit: np.ndarray  # (..., S, P) direct transmission time
    t_bypass: np.ndarray  # (..., S, P) relay-hop carry time
    t_recfg_wait: np.ndarray  # (..., S, P) exposed reconfiguration time
    t_recfg_hidden: np.ndarray  # (..., S, P) overlapped reconfiguration
    t_idle: np.ndarray  # (..., P) closing term (barrier stalls, slack)
    cct: np.ndarray  # (...,) the CCT being decomposed
    step_mask: np.ndarray  # (..., S) bool
    plane_mask: np.ndarray  # (..., P) bool

    @property
    def plane_total(self) -> np.ndarray:
        """Per-plane component sum incl. idle; equals ``cct`` bitwise on
        every unmasked plane (the conservation invariant)."""
        comp = component_sum(
            self.t_xmit, self.t_bypass, self.t_recfg_wait,
            self.t_recfg_hidden,
        )
        return comp + self.t_idle

    @property
    def exposed_recfg(self) -> np.ndarray:
        """Total exposed reconfiguration time per instance, (...)."""
        return self.t_recfg_wait.sum(axis=(-2, -1))

    @property
    def hidden_recfg(self) -> np.ndarray:
        """Total overlapped reconfiguration time per instance, (...)."""
        return self.t_recfg_hidden.sum(axis=(-2, -1))

    @property
    def overlap_efficiency(self) -> np.ndarray:
        """Fraction of reconfiguration time hidden by overlap, (...).

        ``hidden / (hidden + exposed)``; 1.0 when the schedule carries no
        reconfiguration time at all (vacuous overlap).
        """
        hidden = self.hidden_recfg
        total = hidden + self.exposed_recfg
        return np.where(total > 0.0, hidden / np.where(total > 0.0, total, 1.0), 1.0)

    @property
    def bypass_time_fraction(self) -> np.ndarray:
        """Relay-carry share of all transmission time, (...)."""
        byp = self.t_bypass.sum(axis=(-2, -1))
        total = byp + self.t_xmit.sum(axis=(-2, -1))
        return np.where(total > 0.0, byp / np.where(total > 0.0, total, 1.0), 0.0)

    def summary(self) -> str:
        """One-paragraph human rendering (single-instance shapes)."""
        us = 1e6
        cct = float(np.max(self.cct)) if self.cct.ndim else float(self.cct)
        parts = [
            f"cct {cct * us:.1f} us",
            f"xmit {float(self.t_xmit.sum()) * us:.1f} us",
            f"bypass {float(self.t_bypass.sum()) * us:.1f} us",
            f"recfg exposed {float(self.exposed_recfg.sum()) * us:.1f} us",
            f"hidden {float(self.hidden_recfg.sum()) * us:.1f} us",
            f"idle {float(self.t_idle.sum()) * us:.1f} us",
            f"overlap eff {float(np.mean(self.overlap_efficiency)):.2f}",
        ]
        return ", ".join(parts)


def build_attribution(
    cct: np.ndarray,
    t_xmit: np.ndarray,
    t_bypass: np.ndarray,
    t_recfg_wait: np.ndarray,
    t_recfg_hidden: np.ndarray,
    plane_mask: np.ndarray,
    step_mask: np.ndarray,
) -> Attribution:
    """Close the decomposition: derive ``t_idle`` and wrap the arrays.

    The shared epilogue for every timing backend (called from
    ``repro.core.ir.engine.finalize_result``), so the conservation
    construction cannot drift between numpy, jax, and Pallas.
    """
    cct = np.asarray(cct, dtype=np.float64)
    arrs = tuple(
        np.asarray(a, dtype=np.float64)
        for a in (t_xmit, t_bypass, t_recfg_wait, t_recfg_hidden)
    )
    plane_mask = np.asarray(plane_mask, dtype=bool)
    step_mask = np.asarray(step_mask, dtype=bool)
    idle = closing_idle(cct, component_sum(*arrs), plane_mask)
    return Attribution(
        t_xmit=arrs[0],
        t_bypass=arrs[1],
        t_recfg_wait=arrs[2],
        t_recfg_hidden=arrs[3],
        t_idle=idle,
        cct=cct,
        step_mask=step_mask,
        plane_mask=plane_mask,
    )


def attribute(schedule: Schedule) -> Attribution:
    """Decompose one timed ``Schedule`` by walking its activities.

    The object-path oracle for the vectorized attribution in
    ``batch_evaluate(..., attribution=True)``: works on any legal
    schedule (greedy, MILP, arbiter re-plans), not just earliest-start
    ones.  Exposed reconfiguration time uses the counterfactual
    earliest start -- ``max(barrier, r.end) - max(barrier, r.start)``
    against the barrier in force when the reconfiguration's step begins
    -- clamped to ``[0, t_recfg]``.
    """
    n_steps = schedule.pattern.n_steps
    n_planes = schedule.fabric.n_planes
    t_xmit = np.zeros((n_steps, n_planes))
    t_bypass = np.zeros((n_steps, n_planes))
    t_wait = np.zeros((n_steps, n_planes))
    t_hidden = np.zeros((n_steps, n_planes))

    barrier_before = step_barriers(schedule)

    chain = schedule.mode is DependencyMode.CHAIN
    for a in schedule.activities:
        dur = a.end - a.start
        if a.kind is Kind.XMIT:
            if a.route >= 0:
                t_bypass[a.step, a.plane] += dur
            else:
                t_xmit[a.step, a.plane] += dur
        else:
            if chain:
                b = barrier_before[a.step]
                wait = min(max(max(b, a.end) - max(b, a.start), 0.0), dur)
            else:
                wait = dur
            t_wait[a.step, a.plane] += wait
            t_hidden[a.step, a.plane] += dur - wait

    return build_attribution(
        cct=np.float64(schedule.cct),
        t_xmit=t_xmit,
        t_bypass=t_bypass,
        t_recfg_wait=t_wait,
        t_recfg_hidden=t_hidden,
        plane_mask=np.ones(n_planes, dtype=bool),
        step_mask=np.ones(n_steps, dtype=bool),
    )
