"""Observability: CCT attribution, fabric tracing, structured logging.

Three answers to "where did the time go":

* `repro.obs.attribution` -- per-(instance, step, plane) CCT
  decomposition (transmit / bypass / exposed vs. hidden reconfiguration /
  idle) with a bitwise conservation guarantee, from both the vectorized
  engine (``batch_evaluate(..., attribution=True)``) and an object-walk
  oracle (``attribute(schedule)``); the derived *overlap efficiency*
  metric measures the paper's headline directly.
* `repro.obs.trace` -- span/counter instrumentation for the multi-tenant
  runtime behind a no-op default, exported as Chrome trace-event JSON
  (Perfetto-loadable; pid ``fabric``, one thread row per plane).
* `repro.obs.log` -- the structured logger the examples and benchmark
  drivers use (``REPRO_LOG=`` plain | json | debug | quiet).

See DESIGN.md section 16.
"""

from repro.obs.attribution import (
    Attribution,
    attribute,
    build_attribution,
    closing_idle,
    component_sum,
)
from repro.obs.log import ENV_LOG, ObsLogger, get_logger
from repro.obs.trace import (
    JOBS_LANE,
    NULL_TRACER,
    ChromeTracer,
    NullTracer,
    Tracer,
    trace_schedule,
    validate_trace,
    validate_trace_file,
)

__all__ = [
    "Attribution",
    "ChromeTracer",
    "ENV_LOG",
    "JOBS_LANE",
    "NULL_TRACER",
    "NullTracer",
    "ObsLogger",
    "Tracer",
    "attribute",
    "build_attribution",
    "closing_idle",
    "component_sum",
    "get_logger",
    "trace_schedule",
    "validate_trace",
    "validate_trace_file",
]
