"""Observability: CCT attribution, fabric tracing, structured logging.

Three answers to "where did the time go":

* `repro.obs.attribution` -- per-(instance, step, plane) CCT
  decomposition (transmit / bypass / exposed vs. hidden reconfiguration /
  idle) with a bitwise conservation guarantee, from both the vectorized
  engine (``batch_evaluate(..., attribution=True)``) and an object-walk
  oracle (``attribute(schedule)``); the derived *overlap efficiency*
  metric measures the paper's headline directly.
* `repro.obs.trace` -- span/counter instrumentation for the multi-tenant
  runtime behind a no-op default, exported as Chrome trace-event JSON
  (Perfetto-loadable; pid ``fabric``, one thread row per plane).
* `repro.obs.log` -- the structured logger the examples and benchmark
  drivers use (``REPRO_LOG=`` plain | json | debug | quiet).
* `repro.obs.metrics` -- the live metrics substrate: typed Counter /
  Gauge / log-bucketed Histogram instruments with exact associative
  ``merge()``, a ``MetricsRegistry`` with Prometheus-text and JSON
  exporters, and the ``NULL_REGISTRY`` no-op default the runtime hot
  paths are instrumented against.
* `repro.obs.slo` -- per-tenant SLO monitors (deadline targets, windowed
  response-time quantiles via histogram merge, miss counters) layered on
  the metrics substrate.

See DESIGN.md sections 16 and 20.
"""

from repro.obs.attribution import (
    Attribution,
    attribute,
    build_attribution,
    closing_idle,
    component_sum,
    step_barriers,
)
from repro.obs.log import ENV_LOG, ObsLogger, get_logger
from repro.obs.metrics import (
    NULL_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    validate_prometheus_text,
)
from repro.obs.slo import SLOMonitor, SLOTarget, TenantSLO
from repro.obs.trace import (
    JOBS_LANE,
    NULL_TRACER,
    ChromeTracer,
    NullTracer,
    Tracer,
    trace_schedule,
    validate_trace,
    validate_trace_file,
)

__all__ = [
    "Attribution",
    "ChromeTracer",
    "Counter",
    "ENV_LOG",
    "Gauge",
    "Histogram",
    "JOBS_LANE",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NULL_TRACER",
    "NullRegistry",
    "NullTracer",
    "ObsLogger",
    "SLOMonitor",
    "SLOTarget",
    "TenantSLO",
    "Tracer",
    "attribute",
    "build_attribution",
    "closing_idle",
    "component_sum",
    "get_logger",
    "step_barriers",
    "trace_schedule",
    "validate_prometheus_text",
    "validate_trace",
    "validate_trace_file",
]
