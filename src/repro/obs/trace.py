"""Structured fabric tracing: spans + counters -> Chrome trace-event JSON.

The multi-tenant runtime is an event-driven simulation; debugging lease
churn or backpressure from aggregate statistics alone is guesswork.  This
module gives the runtime (and the single-collective demos) a tracer with
three primitives:

* ``span(name, t0, t1, tid)``    -- a complete duration event ("X");
* ``instant(name, t, tid)``      -- a point event ("i");
* ``counter(name, t, value)``    -- a time series sample ("C").

The default is ``NULL_TRACER``, whose ``enabled`` flag is False:
instrumentation sites guard with ``if tracer.enabled`` so the disabled
cost is one attribute load per site -- the quick-bench regression band
(25%) gates this staying negligible.

``ChromeTracer`` records events in memory and exports the Chrome
trace-event JSON format (https://ui.perfetto.dev loads it directly): one
process row named ``fabric`` (pid 1), one thread row per optical plane
(tid = plane index) plus a ``jobs`` lane for admission-level events.
Simulated seconds become microsecond timestamps.

``validate_trace`` is the schema checker the tests and the CI smoke job
share: it verifies the exported payload is structurally a trace-event
file (required keys per phase type, numeric timestamps, known lanes)
without depending on Perfetto.
"""

from __future__ import annotations

import json
from typing import Any

# Lane (Chrome "thread") ids that are not plane indices.
JOBS_LANE = 1000
_PID = 1


class Tracer:
    """No-op tracer base; also the disabled-path implementation."""

    enabled: bool = False

    def span(
        self,
        name: str,
        t0: float,
        t1: float,
        tid: int = JOBS_LANE,
        **args: Any,
    ) -> None:
        pass

    def instant(
        self, name: str, t: float, tid: int = JOBS_LANE, **args: Any
    ) -> None:
        pass

    def counter(self, name: str, t: float, value: float) -> None:
        pass


class NullTracer(Tracer):
    """Explicit name for the default no-op tracer."""


NULL_TRACER = NullTracer()


class ChromeTracer(Tracer):
    """In-memory recorder exporting Chrome trace-event JSON.

    Usable as a context manager: ``with ChromeTracer(path="out.json") as
    tracer: ...`` writes the trace on exit *even when the body raises*,
    so a demo that crashes mid-replay still leaves a valid, validatable
    trace of everything recorded up to the failure.
    """

    enabled = True

    def __init__(
        self, process_name: str = "fabric", path: str | None = None
    ) -> None:
        self.process_name = process_name
        self.path = path
        self.events: list[dict[str, Any]] = []
        self._named_lanes: dict[int, str] = {}

    def __enter__(self) -> "ChromeTracer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Flush on both clean exit and exception; never swallow the
        # in-flight exception (returning None propagates it).
        if self.path is not None:
            self.write(self.path)

    # -- recording ----------------------------------------------------------
    def span(
        self,
        name: str,
        t0: float,
        t1: float,
        tid: int = JOBS_LANE,
        **args: Any,
    ) -> None:
        self.events.append(
            {
                "name": name,
                "ph": "X",
                "ts": t0 * 1e6,
                "dur": max(t1 - t0, 0.0) * 1e6,
                "pid": _PID,
                "tid": tid,
                "args": args,
            }
        )

    def instant(
        self, name: str, t: float, tid: int = JOBS_LANE, **args: Any
    ) -> None:
        self.events.append(
            {
                "name": name,
                "ph": "i",
                "ts": t * 1e6,
                "pid": _PID,
                "tid": tid,
                "s": "t",  # thread-scoped instant
                "args": args,
            }
        )

    def counter(self, name: str, t: float, value: float) -> None:
        self.events.append(
            {
                "name": name,
                "ph": "C",
                "ts": t * 1e6,
                "pid": _PID,
                "args": {"value": value},
            }
        )

    def name_lane(self, tid: int, name: str) -> None:
        """Label a thread row (``plane 3``, ``jobs``) in the viewer."""
        self._named_lanes[tid] = name

    # -- export -------------------------------------------------------------
    def to_json(self) -> dict[str, Any]:
        """The trace-event payload (metadata + recorded events)."""
        lanes = dict(self._named_lanes)
        lanes.setdefault(JOBS_LANE, "jobs")
        for ev in self.events:
            tid = ev.get("tid")
            if tid is not None and tid not in lanes:
                lanes[tid] = f"plane {tid}"
        meta: list[dict[str, Any]] = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": _PID,
                "args": {"name": self.process_name},
            }
        ]
        for tid in sorted(lanes):
            meta.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": _PID,
                    "tid": tid,
                    "args": {"name": lanes[tid]},
                }
            )
        return {
            "traceEvents": meta + self.events,
            "displayTimeUnit": "ms",
        }

    def write(self, path: str) -> None:
        payload = self.to_json()
        validate_trace(payload)
        with open(path, "w") as fh:
            json.dump(payload, fh)


_REQUIRED = {
    "X": ("name", "ts", "dur", "pid", "tid"),
    "i": ("name", "ts", "pid", "tid"),
    "C": ("name", "ts", "pid", "args"),
    "M": ("name", "pid", "args"),
}


def validate_trace(payload: dict[str, Any]) -> None:
    """Raise ``ValueError`` unless ``payload`` is a well-formed trace.

    Checks the structural contract Perfetto's legacy-JSON importer
    relies on: a ``traceEvents`` list, a known phase per event, the
    phase's required keys, numeric non-negative timestamps/durations,
    and exactly one ``process_name`` metadata record.
    """
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("trace payload must be a dict with 'traceEvents'")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    n_process = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph not in _REQUIRED:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        for key in _REQUIRED[ph]:
            if key not in ev:
                raise ValueError(f"event {i} (ph={ph}) missing {key!r}")
        for key in ("ts", "dur"):
            if key in ev:
                val = ev[key]
                if not isinstance(val, (int, float)) or val < 0:
                    raise ValueError(
                        f"event {i} has non-numeric/negative {key!r}: {val!r}"
                    )
        if ph == "M" and ev["name"] == "process_name":
            n_process += 1
        if ph == "C" and "value" not in ev["args"]:
            raise ValueError(f"counter event {i} missing args.value")
    if n_process != 1:
        raise ValueError(
            f"expected exactly one process_name record, found {n_process}"
        )


def validate_trace_file(path: str) -> None:
    """``validate_trace`` for a file on disk (the CI smoke entry point)."""
    with open(path) as fh:
        validate_trace(json.load(fh))


def trace_schedule(schedule, tracer: ChromeTracer, t0: float = 0.0) -> None:
    """Emit one timed ``Schedule``'s activities as spans (demo traces).

    Planes map to thread rows exactly like the runtime tracer, so a
    single-collective plan and a multi-tenant replay render the same way
    in Perfetto.
    """
    from repro.core.schedule import Kind

    for a in schedule.activities:
        if a.kind is Kind.RECFG:
            tracer.span(
                f"reconfig->c{a.config}",
                t0 + a.start,
                t0 + a.end,
                tid=a.plane,
                step=a.step,
            )
        elif a.route >= 0:
            tracer.span(
                f"bypass r{a.route}h{a.hop}",
                t0 + a.start,
                t0 + a.end,
                tid=a.plane,
                step=a.step,
                volume=a.volume,
            )
        else:
            tracer.span(
                f"xmit s{a.step}",
                t0 + a.start,
                t0 + a.end,
                tid=a.plane,
                step=a.step,
                volume=a.volume,
            )


def main(argv: list[str] | None = None) -> int:
    """CLI validator: ``python -m repro.obs.trace trace.json [...]``."""
    import sys

    paths = argv if argv is not None else sys.argv[1:]
    if not paths:
        print("usage: python -m repro.obs.trace TRACE.json [...]")
        return 2
    for path in paths:
        validate_trace_file(path)
        print(f"{path}: valid trace-event JSON")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
