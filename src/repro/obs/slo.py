"""Per-tenant SLO monitoring on top of the metrics substrate.

An ``SLOMonitor`` watches completed/rejected ``JobRecord``s as they
retire (one ``observe`` per record -- works identically in accumulated
and streaming replay) and answers two questions per tenant:

* **miss rate** -- the fraction of jobs violating that tenant's
  ``SLOTarget`` (a response-time deadline, measured arrival->finish;
  rejected jobs always count as misses);
* **windowed latency quantiles** -- p50/p95/p99 over the last *k* time
  windows, computed by merging per-window log-bucketed histograms
  (exact merge, so "last 3 windows" equals one histogram that observed
  those windows directly; error bounds are the histogram's).

Window bookkeeping is constant-memory: each (tenant, window) pair keeps
one bounded histogram and the monitor retains at most ``max_windows``
windows per tenant, evicting the oldest.  Cumulative counters (jobs,
misses) are fed to the registry at observe time, so eviction never
loses totals.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Iterable, Mapping

from .metrics import (
    DEFAULT_RESOLUTION,
    MetricsRegistry,
    NULL_REGISTRY,
    _HistogramValue,
)

__all__ = ["SLOTarget", "SLOMonitor", "TenantSLO"]


@dataclass(frozen=True)
class SLOTarget:
    """A tenant's service objective.

    ``deadline``: max acceptable response time (arrival -> finish),
    seconds; ``None`` disables deadline checking (only rejections
    count as misses).
    """

    deadline: float | None = None

    def __post_init__(self) -> None:
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be > 0: {self.deadline}")


@dataclass
class TenantSLO:
    """Snapshot row: one tenant's SLO standing."""

    tenant: str
    target: SLOTarget
    n_jobs: int
    n_miss: int
    p50_response: float
    p95_response: float
    p99_response: float

    @property
    def miss_rate(self) -> float:
        return self.n_miss / self.n_jobs if self.n_jobs else 0.0


class _TenantState:
    __slots__ = ("n_jobs", "n_miss", "windows")

    def __init__(self) -> None:
        self.n_jobs = 0
        self.n_miss = 0
        # window index -> response-time histogram (insertion-ordered,
        # so eviction pops the oldest window first).
        self.windows: dict[int, _HistogramValue] = {}


class SLOMonitor:
    """Tracks per-tenant deadline misses and windowed latency quantiles.

    ``targets`` maps tenant name -> ``SLOTarget``; tenants not listed
    fall back to ``default`` (or to rejection-only monitoring when no
    default is given).  ``window`` is the bucketing period in sim
    seconds; ``max_windows`` bounds retained history per tenant.

    Pass a ``MetricsRegistry`` to additionally publish
    ``slo_jobs_total{tenant}``, ``slo_deadline_miss_total{tenant}`` and
    the ``slo_miss_rate{tenant}`` gauge on every observation.
    """

    def __init__(
        self,
        targets: Mapping[str, SLOTarget] | None = None,
        *,
        default: SLOTarget | None = None,
        window: float = 60.0,
        max_windows: int = 16,
        resolution: int = DEFAULT_RESOLUTION,
        registry: MetricsRegistry | None = None,
    ) -> None:
        if window <= 0:
            raise ValueError(f"window must be > 0: {window}")
        if max_windows < 1:
            raise ValueError(f"max_windows must be >= 1: {max_windows}")
        self.targets = dict(targets or {})
        self.default = default
        self.window = float(window)
        self.max_windows = max_windows
        self.resolution = resolution
        self._tenants: dict[str, _TenantState] = {}
        reg = registry if registry is not None else NULL_REGISTRY
        self._m_on = reg.enabled
        self._m_jobs = reg.counter(
            "slo_jobs_total", "Jobs observed by the SLO monitor",
            ("tenant",),
        )
        self._m_miss = reg.counter(
            "slo_deadline_miss_total",
            "Jobs that missed their tenant SLO (deadline or rejection)",
            ("tenant",),
        )
        self._m_rate = reg.gauge(
            "slo_miss_rate", "Current per-tenant SLO miss fraction",
            ("tenant",),
        )

    def target_for(self, tenant: str) -> SLOTarget:
        target = self.targets.get(tenant, self.default)
        return target if target is not None else SLOTarget()

    def observe(self, record: Any) -> bool:
        """Fold one retired ``JobRecord`` in; returns True on a miss."""
        tenant = record.tenant
        state = self._tenants.get(tenant)
        if state is None:
            state = self._tenants[tenant] = _TenantState()
        state.n_jobs += 1
        target = self.target_for(tenant)
        if record.rejected:
            miss = True
        else:
            response = record.finish - record.arrival
            miss = (
                target.deadline is not None and response > target.deadline
            )
            idx = int(record.finish // self.window)
            hist = state.windows.get(idx)
            if hist is None:
                hist = state.windows[idx] = _HistogramValue(
                    self.resolution
                )
                while len(state.windows) > self.max_windows:
                    state.windows.pop(next(iter(state.windows)))
            hist.observe(response)
        if miss:
            state.n_miss += 1
        if self._m_on:
            self._m_jobs.labels(tenant).inc()
            if miss:
                self._m_miss.labels(tenant).inc()
            self._m_rate.labels(tenant).set(state.n_miss / state.n_jobs)
        return miss

    # -- queries ------------------------------------------------------------
    def tenants(self) -> tuple[str, ...]:
        return tuple(sorted(self._tenants))

    def miss_rate(self, tenant: str) -> float:
        state = self._tenants.get(tenant)
        if state is None or state.n_jobs == 0:
            return 0.0
        return state.n_miss / state.n_jobs

    def window_histogram(
        self, tenant: str, *, last: int | None = None
    ) -> _HistogramValue:
        """Response-time distribution over the last ``last`` retained
        windows (all retained windows when ``None``), as one exact
        histogram merge."""
        out = _HistogramValue(self.resolution)
        state = self._tenants.get(tenant)
        if state is None:
            return out
        indices = sorted(state.windows)
        if last is not None:
            if last < 1:
                raise ValueError(f"last must be >= 1: {last}")
            indices = indices[-last:]
        for idx in indices:
            out.merge_from(state.windows[idx])
        return out

    def window_quantiles(
        self,
        tenant: str,
        qs: Iterable[float] = (0.5, 0.95, 0.99),
        *,
        last: int | None = None,
    ) -> tuple[float, ...]:
        hist = self.window_histogram(tenant, last=last)
        return tuple(hist.quantile(q) for q in qs)

    def snapshot(self) -> dict[str, TenantSLO]:
        """Per-tenant standing: totals plus whole-history quantiles."""
        out: dict[str, TenantSLO] = {}
        for tenant in self.tenants():
            state = self._tenants[tenant]
            p50, p95, p99 = self.window_quantiles(tenant)
            out[tenant] = TenantSLO(
                tenant=tenant,
                target=self.target_for(tenant),
                n_jobs=state.n_jobs,
                n_miss=state.n_miss,
                p50_response=p50,
                p95_response=p95,
                p99_response=p99,
            )
        return out

    def summary(self) -> str:
        rows = ["tenant            jobs  miss  rate   p50        p95        p99"]
        for tenant, row in self.snapshot().items():
            rows.append(
                f"{tenant:<16} {row.n_jobs:>5} {row.n_miss:>5} "
                f"{row.miss_rate:>5.1%}  "
                f"{_fmt_s(row.p50_response)}  {_fmt_s(row.p95_response)}  "
                f"{_fmt_s(row.p99_response)}"
            )
        return "\n".join(rows)


def _fmt_s(seconds: float) -> str:
    if math.isnan(seconds):
        return "      nan"
    if seconds < 1e-3:
        return f"{seconds * 1e6:>7.1f}us"
    if seconds < 1.0:
        return f"{seconds * 1e3:>7.2f}ms"
    return f"{seconds:>7.3f}s "
