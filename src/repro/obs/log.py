"""Structured logging for examples and benchmarks: one knob, ``REPRO_LOG``.

The demos and benchmark drivers used ad-hoc ``print()`` calls -- fine
until output needs to be quieted in CI, grepped by tooling, or rendered
as JSON lines.  This logger replaces them with two channels:

* ``info`` / ``debug`` / ``warning`` -- *narrative* output (progress,
  summaries, timelines).  Rendering follows ``REPRO_LOG``:

  - unset or ``plain``  -- the message followed by ``key=value`` fields;
  - ``json``            -- one JSON object per line
    (``{"level", "logger", "msg", ...fields}``);
  - ``debug``           -- plain, plus ``debug``-level records;
  - ``quiet`` or ``0``  -- ``info``/``debug`` suppressed (warnings kept).

* ``data`` -- *program output* (the benchmark CSV rows).  Always printed
  verbatim to stdout regardless of ``REPRO_LOG``: machine-readable
  output is the program's contract, not a log.

Stateless by design: the knob is re-read per record, so tests can
monkeypatch the environment without reloading modules.
"""

from __future__ import annotations

import json
import sys
from typing import Any, TextIO

from repro.core import knobs
from repro.core.knobs import ENV_LOG  # noqa: F401  (compat re-export)

_LEVELS = {"debug": 10, "info": 20, "warning": 30}


def _mode() -> str:
    return knobs.log_mode()


def _threshold(mode: str) -> int:
    if mode in ("quiet", "0", "off"):
        return _LEVELS["warning"]
    if mode == "debug":
        return _LEVELS["debug"]
    return _LEVELS["info"]


class ObsLogger:
    """A named logger writing narrative records per the ``REPRO_LOG`` knob."""

    def __init__(self, name: str, stream: TextIO | None = None) -> None:
        self.name = name
        self._stream = stream

    # -- narrative channel --------------------------------------------------
    def _emit(self, level: str, msg: str, fields: dict[str, Any]) -> None:
        mode = _mode()
        if _LEVELS[level] < _threshold(mode):
            return
        stream = self._stream or (
            sys.stderr if level == "warning" else sys.stdout
        )
        if mode == "json":
            record = {"level": level, "logger": self.name, "msg": msg}
            record.update(fields)
            print(json.dumps(record, default=str), file=stream)
            return
        parts = [msg] if msg else []
        parts.extend(f"{k}={v}" for k, v in fields.items())
        print(" ".join(parts), file=stream)

    def debug(self, msg: str = "", **fields: Any) -> None:
        self._emit("debug", msg, fields)

    def info(self, msg: str = "", **fields: Any) -> None:
        self._emit("info", msg, fields)

    def warning(self, msg: str = "", **fields: Any) -> None:
        self._emit("warning", msg, fields)

    # -- data channel -------------------------------------------------------
    def data(self, line: str) -> None:
        """Machine-readable program output (CSV rows): never filtered,
        never reformatted, always stdout (flushed: CI tails the rows
        while slow sweeps run)."""
        print(line, file=self._stream or sys.stdout, flush=True)


_loggers: dict[str, ObsLogger] = {}


def get_logger(name: str) -> ObsLogger:
    """The process-wide logger for ``name`` (benchmark/demo module)."""
    if name not in _loggers:
        _loggers[name] = ObsLogger(name)
    return _loggers[name]
