"""JAX-native multi-step collectives matching the paper's patterns.

Each algorithm here is the executable twin of a `repro.core.patterns`
pattern: the same bijective-pairing step sequence, realized with
``lax.ppermute`` inside ``shard_map``.  One source of truth connects the
optical scheduler (which times the steps) and the runtime (which runs
them): ``pattern_for`` returns the core pattern whose step/volume
structure matches what the collective will transmit.

All functions are *per-device* bodies: call them inside ``shard_map``
with the relevant mesh axis, or use the ``*_sharded`` wrappers.  They are
validated against ``lax.psum`` / ``lax.all_to_all`` oracles on 8 host
devices (tests/test_comms.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.patterns import (
    Pattern,
    bruck_alltoall,
    pairwise_alltoall,
    rabenseifner_allreduce,
    ring_allreduce,
)


def _axis_size(axis: str) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    # Older JAX: the bound axis size is on the env frame via psum of 1.
    return lax.psum(1, axis)


def _rotation_perm(n: int, shift: int) -> list[tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


def _xor_perm(n: int, mask: int) -> list[tuple[int, int]]:
    return [(i, i ^ mask) for i in range(n)]


# ---------------------------------------------------------------------------
# Ring all-reduce: 2(N-1) steps, single rotation config.


def ring_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Bandwidth-optimal ring AllReduce (reduce-scatter + all-gather)."""
    n = _axis_size(axis)
    if n == 1:
        return x
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    rank = lax.axis_index(axis)
    perm = _rotation_perm(n, 1)

    # Reduce-scatter ring: the travelling partial passes rank -> rank+1;
    # at step t rank r receives the partial of chunk (r - t) mod n and
    # adds its own contribution.  After n-1 steps r owns chunk (r+1) % n.
    acc = jnp.take(chunks, rank, axis=0)
    for t in range(1, n):
        acc = lax.ppermute(acc, axis, perm)
        acc = acc + jnp.take(chunks, (rank - t) % n, axis=0)
    out = jnp.zeros_like(chunks)
    out = out.at[(rank + 1) % n].set(acc)
    # All-gather ring: n-1 rotations forwarding the newest chunk; at step
    # s rank r receives the fully-reduced chunk (r + 1 - s) mod n.
    cur = acc
    for s in range(1, n):
        cur = lax.ppermute(cur, axis, perm)
        out = out.at[(rank + 1 - s) % n].set(cur)
    flat_out = out.reshape(-1)
    if pad:
        flat_out = flat_out[: flat.size - pad]
    return flat_out.reshape(orig_shape)


# ---------------------------------------------------------------------------
# Rabenseifner all-reduce: recursive halving + recursive doubling.


def rabenseifner_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    n = _axis_size(axis)
    if n == 1:
        return x
    log = n.bit_length() - 1
    if 1 << log != n:
        raise ValueError(f"rabenseifner needs power-of-two ranks, got {n}")
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    total = flat.size
    rank = lax.axis_index(axis)

    # Reduce-scatter phase (recursive halving): window [off, off+size).
    buf = flat
    off = jnp.zeros((), jnp.int32)
    size = total
    for t in range(1, log + 1):
        mask = 1 << (t - 1)
        size //= 2
        bit = (rank >> (t - 1)) & 1
        keep_off = off + bit * size
        send_off = off + (1 - bit) * size
        send = lax.dynamic_slice(buf, (send_off,), (size,))
        recv = lax.ppermute(send, axis, _xor_perm(n, mask))
        kept = lax.dynamic_slice(buf, (keep_off,), (size,))
        buf = lax.dynamic_update_slice(buf, kept + recv, (keep_off,))
        off = keep_off
    # Rank now owns the reduced segment [off, off+size).

    # All-gather phase (recursive doubling), reversing the halving.
    for t in range(log, 0, -1):
        mask = 1 << (t - 1)
        bit = (rank >> (t - 1)) & 1
        send = lax.dynamic_slice(buf, (off,), (size,))
        recv = lax.ppermute(send, axis, _xor_perm(n, mask))
        partner_off = off + jnp.where(bit == 1, -size, size)
        buf = lax.dynamic_update_slice(buf, recv, (partner_off,))
        off = jnp.minimum(off, partner_off)
        size *= 2
    if pad:
        buf = buf[: total - pad]
    return buf.reshape(orig_shape)


# ---------------------------------------------------------------------------
# Pairwise all-to-all: N-1 steps, all configs distinct.


def pairwise_all_to_all(x: jax.Array, axis: str) -> jax.Array:
    """x: (N, ...) chunk c goes to rank c; returns gathered (N, ...)."""
    n = _axis_size(axis)
    if n == 1:
        return x
    rank = lax.axis_index(axis)
    out = jnp.zeros_like(x)
    out = out.at[rank].set(jnp.take(x, rank, axis=0))
    for k in range(1, n):
        send = jnp.take(x, (rank + k) % n, axis=0)  # chunk for rank+k
        recv = lax.ppermute(send, axis, _rotation_perm(n, k))
        out = out.at[(rank - k) % n].set(recv)
    return out


# ---------------------------------------------------------------------------
# Bruck all-to-all: ceil(log2 N) phases of rotation-by-2^k sends.


def bruck_all_to_all(x: jax.Array, axis: str) -> jax.Array:
    """x: (N, ...) chunk c goes to rank c; returns gathered (N, ...)."""
    n = _axis_size(axis)
    if n == 1:
        return x
    rank = lax.axis_index(axis)
    # Local rotation: y[o] = block destined to rank (rank + o) mod n.
    offsets = (rank + jnp.arange(n)) % n
    y = jnp.take(x, offsets, axis=0)
    n_phases = max(1, math.ceil(math.log2(n)))
    for k in range(n_phases):
        step = 1 << k
        slots = [o for o in range(n) if (o >> k) & 1]
        if not slots:
            continue
        send = y[jnp.array(slots)]
        recv = lax.ppermute(send, axis, _rotation_perm(n, step))
        y = y.at[jnp.array(slots)].set(recv)
    # y[o] now holds the block from rank (rank - o) destined to us;
    # un-rotate into source order.
    sources = (rank - jnp.arange(n)) % n
    out = jnp.zeros_like(y)
    out = out.at[sources].set(y)
    return out


# ---------------------------------------------------------------------------
# Hierarchical all-reduce for multi-pod meshes.


def hierarchical_all_reduce(
    x: jax.Array, inner_axis: str, outer_axis: str
) -> jax.Array:
    """Reduce-scatter intra-pod, all-reduce across pods, all-gather back.

    The cross-pod traffic is 1/N_inner of the naive flat all-reduce --
    the standard topology-aware schedule for pod-scale DP (DESIGN.md
    section 4).
    """
    n = _axis_size(inner_axis)
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = lax.psum_scatter(
        flat.reshape(n, -1), inner_axis, scatter_dimension=0, tiled=False
    )  # (chunk,) this rank's reduced shard
    shard = lax.psum(shard, outer_axis)
    gathered = lax.all_gather(shard, inner_axis, axis=0, tiled=False)
    flat_out = gathered.reshape(-1)
    if pad:
        flat_out = flat_out[: flat.size - pad]
    return flat_out.reshape(orig_shape)


# ---------------------------------------------------------------------------
# Pattern handoff to the SWOT scheduler.

ALGORITHM_PATTERNS = {
    "ring_all_reduce": ring_allreduce,
    "rabenseifner_all_reduce": rabenseifner_allreduce,
    "pairwise_all_to_all": pairwise_alltoall,
    "bruck_all_to_all": bruck_alltoall,
}


def pattern_for(algorithm: str, n_nodes: int, size_bytes: float) -> Pattern:
    """The core Pattern whose steps this collective will transmit."""
    return ALGORITHM_PATTERNS[algorithm](n_nodes, size_bytes)
