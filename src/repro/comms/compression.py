"""Gradient compression: int8 quantized all-reduce with error feedback.

Distributed-optimization trick for the DP gradient sync (DESIGN.md
section 4): gradients are blockwise int8-quantized before the wire
(4x fewer collective bytes than bf16, 2x fewer than... fp16), with the
quantization residual fed back into the next step so the error does not
accumulate (EF-SGD style).

``compressed_all_reduce`` performs mean-reduction over the axis with int8
payloads: quantize locally, all-to-all-style exchange via ppermute ring
summation in f32, requantize only on the wire.  The simpler
``quantize_block``/``dequantize_block`` pair is also used by the
checkpoint codec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

BLOCK = 2048


def quantize_block(
    x: jax.Array, block: int = BLOCK
) -> tuple[jax.Array, jax.Array, int]:
    """Blockwise symmetric int8: returns (q, scales, orig_size)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def dequantize_block(
    q: jax.Array, scale: jax.Array, n: int, shape: tuple[int, ...]
) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_decompress(x: jax.Array) -> jax.Array:
    """Round-trip through the wire format (for error analysis/tests)."""
    q, s, n = quantize_block(x)
    return dequantize_block(q, s, n, x.shape)


def compressed_all_reduce(
    x: jax.Array,
    axis: str,
    error: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Mean all-reduce with int8 wire format + error feedback.

    Returns (mean_reduced, new_error).  ``error`` is the residual pytree
    leaf from the previous step (zeros initially).  Per-device math:

        send    = quantize(x + error)
        error'  = (x + error) - dequantize(send)
        result  = ring-sum of dequantized payloads / N
    """
    from repro.comms.algorithms import _axis_size

    n = _axis_size(axis)
    if error is None:
        error = jnp.zeros_like(x)
    target = x + error
    q, scale, size = quantize_block(target)
    wire = dequantize_block(q, scale, size, x.shape)
    new_error = target - wire
    if n == 1:
        return wire, new_error
    # Ring summation of the wire values: each hop transfers the int8
    # payload (q, scale); accumulation stays f32 locally.
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = wire
    q_cur, s_cur = q, scale
    for _ in range(n - 1):
        q_cur = lax.ppermute(q_cur, axis, perm)
        s_cur = lax.ppermute(s_cur, axis, perm)
        acc = acc + dequantize_block(q_cur, s_cur, size, x.shape)
    return acc / n, new_error


def wire_bytes(x: jax.Array) -> int:
    """Bytes on the wire for the compressed format (vs 4*size for f32)."""
    q, scale, _ = quantize_block(x)
    return q.size + scale.size * 4
