"""whisper-small [audio]: 12+12L d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865 -- encoder-decoder, conv frontend STUB.
[arXiv:2212.04356; unverified]

``input_specs()`` provides precomputed frame embeddings (B, 1500, 768)
in place of the mel+conv frontend.  Learned decoder positions (448-entry
table, clamped beyond -- the assigned 32k decode cells exercise the KV
cache, not the position table).  Vocab 51865 pads to 51968 (x128) so it
shards 16 ways.  Full attention => ``long_500k`` skipped; 12 heads fall
back to replicated attention on the 16-way model axis.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_small",
    family="audio",
    n_layers=12,
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    qkv_bias=True,
    use_rope=False,
    learned_pos=448,
    n_audio_frames=1500,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    n_encoder_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=3,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    vocab_pad_multiple=8,
    learned_pos=64,
    n_audio_frames=32,
    attn_q_block=32,
    attn_kv_block=32,
)
