"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000 -- GeGLU, head_dim=256, embeddings scaled by sqrt(d),
(1+w) RMSNorm.  [arXiv:2403.08295; hf]

Pure full attention => ``long_500k`` skipped.  8 q-heads / 1 kv-head are
not divisible by the 16-way model axis: the sharding rules engine
replicates attention heads and shards the 16384-wide FFN + 256000 vocab
instead (DESIGN.md section 7).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma_2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    embed_scale=True,
    rms_offset=True,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    vocab_pad_multiple=8,
    attn_q_block=32,
    attn_kv_block=32,
)
