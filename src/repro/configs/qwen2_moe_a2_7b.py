"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Expert parallelism: 60 experts padded to 64 over the 16-way model axis
(4 local experts / device; padded experts masked out of routing).  The
4 shared experts are modeled as one dense FFN of width 4*1408 = 5632
(the HF config's shared_expert_intermediate_size).  The EP all_to_all
emitted per MoE layer is the SWOT planner's pairwise/Bruck-schedulable
collective -- the paper-representative arch.  Full attention =>
``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_moe_a2_7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=5632,  # shared-expert path width
    vocab_size=151936,
    act="silu",
    qkv_bias=True,
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    fsdp_params=True,
    shared_d_ff=5632,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=96,
    n_experts=6,
    top_k=2,
    moe_d_ff=32,
    n_shared_experts=1,
    shared_d_ff=96,
    vocab_size=256,
    vocab_pad_multiple=8,
    attn_q_block=32,
    attn_kv_block=32,
)
