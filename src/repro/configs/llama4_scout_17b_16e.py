"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, 16 routed experts top-1 + 1 shared expert, vocab=202048,
early fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

~109B total / ~17B active parameters.  Experts shard 1:1 over the 16-way
model axis (EP); expert FFN width additionally shards over the data axes
(FSDP-style per-layer all-gather) so bf16 weights fit the 16 GB/chip
budget.  Early fusion uses the same precomputed-patch stub as pixtral.
The assignment line specifies full attention ("MoE, early fusion"), so
``long_500k`` is skipped (DESIGN.md).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4_scout_17b_16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # shared-expert path width
    vocab_size=202048,
    act="silu",
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    n_shared_experts=1,
    shared_d_ff=8192,
    fsdp_experts=True,
    fsdp_params=True,
    rope_theta=5e5,
    tie_embeddings=False,
    n_image_patches=256,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    n_experts=4,
    top_k=1,
    moe_d_ff=128,
    n_shared_experts=1,
    shared_d_ff=128,
    fsdp_experts=False,
    vocab_size=256,
    vocab_pad_multiple=8,
    n_image_patches=8,
    attn_q_block=32,
    attn_kv_block=32,
)
