"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*768 = 1536, head_dim 64 => 24 SSD heads (not divisible by the
16-way model axis; the rules engine replicates SSM heads -- the model is
130M params, so replication is cheap).  Decode state is O(1) in sequence
length: all decode cells incl. ``long_500k`` run.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    ssm_state=16,
    ssm_head_dim=16,
    vocab_size=256,
    vocab_pad_multiple=8,
    ssm_chunk=16,
)
