"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 -- pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

The vision encoder is a STUB per the assignment: ``input_specs()``
provides precomputed, projected patch embeddings (B, 256, d_model) that
replace the first 256 token positions (early fusion).  Pure full
attention => ``long_500k`` skipped.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral_12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    act="silu",
    rope_theta=1e9,
    tie_embeddings=False,
    fsdp_params=True,
    n_image_patches=256,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=8,
    n_image_patches=8,
    attn_q_block=32,
    attn_kv_block=32,
)
