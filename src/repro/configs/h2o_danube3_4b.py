"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 -- llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

SWA (window 4096) is sub-quadratic: the KV cache is a 4096-slot ring
buffer, so ``long_500k`` RUNS for this arch (DESIGN.md shape skips).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o_danube3_4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    act="silu",
    sliding_window=4096,
    rope_theta=1e5,
    tie_embeddings=False,
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=8,
    sliding_window=16,
    attn_q_block=32,
    attn_kv_block=32,
)
