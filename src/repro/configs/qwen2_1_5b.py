"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 -- GQA with QKV bias.  [arXiv:2407.10671; hf]

Pure full attention => ``long_500k`` skipped.  12 q-heads / 2 kv-heads
fall back to replicated attention on the 16-way model axis.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_1_5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    act="silu",
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    vocab_pad_multiple=8,
    attn_q_block=32,
    attn_kv_block=32,
)
