"""Input builders per (arch x shape) cell.

``input_specs`` returns allocation-free ``ShapeDtypeStruct`` stand-ins for
every model input of a cell (the dry-run path); ``make_batch`` builds small
concrete random batches (the smoke-test / example path).  Modality
frontends are stubs per the assignment: VLM cells get precomputed patch
embeddings, audio cells get precomputed encoder frames.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell

COMPUTE_DTYPE = jnp.bfloat16


def _extras_shapes(
    cfg: ArchConfig, batch: int
) -> dict[str, tuple[tuple[int, ...], object]]:
    extras: dict = {}
    if cfg.family in ("vlm",) or (
        cfg.family == "moe" and cfg.n_image_patches
    ):
        extras["image_embeds"] = (
            (batch, cfg.n_image_patches, cfg.d_model),
            COMPUTE_DTYPE,
        )
    if cfg.family == "audio":
        extras["encoder_frames"] = (
            (batch, cfg.n_audio_frames, cfg.d_model),
            COMPUTE_DTYPE,
        )
    return extras


def train_batch_shapes(cfg: ArchConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    shapes = {
        "tokens": ((b, s), jnp.int32),
        "targets": ((b, s), jnp.int32),
    }
    shapes.update(_extras_shapes(cfg, b))
    return shapes


def prefill_batch_shapes(cfg: ArchConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    shapes = {"tokens": ((b, s), jnp.int32)}
    shapes.update(_extras_shapes(cfg, b))
    return shapes


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct tree for the cell's step-function inputs."""
    if cell.kind == "train":
        shapes = train_batch_shapes(cfg, cell)
    elif cell.kind == "prefill":
        shapes = prefill_batch_shapes(cfg, cell)
    elif cell.kind == "decode":
        shapes = {"tokens": ((cell.global_batch, 1), jnp.int32)}
    else:
        raise ValueError(f"unknown cell kind {cell.kind!r}")
    return {
        k: jax.ShapeDtypeStruct(shape, dtype)
        for k, (shape, dtype) in shapes.items()
    }


def make_batch(cfg: ArchConfig, cell: ShapeCell, key: jax.Array) -> dict:
    """Concrete random batch (smoke tests, examples)."""
    if cell.kind == "train":
        shapes = train_batch_shapes(cfg, cell)
    elif cell.kind == "prefill":
        shapes = prefill_batch_shapes(cfg, cell)
    else:
        shapes = {"tokens": ((cell.global_batch, 1), jnp.int32)}
    batch = {}
    for name, (shape, dtype) in shapes.items():
        key, sub = jax.random.split(key)
        if dtype == jnp.int32:
            batch[name] = jax.random.randint(
                sub, shape, 1, cfg.vocab_size, dtype=jnp.int32
            )
        else:
            batch[name] = jax.random.normal(sub, shape, dtype)
    return batch
