"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936 -- qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]

Pure full attention => ``long_500k`` is skipped (DESIGN.md shape skips).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    act="silu",
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = CONFIG.replace(
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=8,
    attn_q_block=32,
    attn_kv_block=32,
)
