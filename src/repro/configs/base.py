"""Architecture configuration schema.

One ``ArchConfig`` per assigned architecture (see ``repro.configs.registry``)
plus reduced variants for smoke tests.  Every field corresponds to a public
config of the source model; deviations are documented in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) evaluation cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def shape_cell(name: str) -> ShapeCell:
    for cell in SHAPES:
        if cell.name == name:
            return cell
    raise KeyError(f"unknown shape {name!r}; have {[c.name for c in SHAPES]}")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // n_heads
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU / plain MLP)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int | None = None
    tie_embeddings: bool = True
    embed_scale: bool = False  # Gemma: embeddings * sqrt(d_model)
    rms_offset: bool = False  # Gemma: (1 + w) RMSNorm
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    fsdp_experts: bool = False
    fsdp_params: bool = False  # ZeRO/FSDP: shard params+opt over data
    moe_token_slice: bool = False  # EP token slicing (Perf lever)
    aux_loss_coef: float = 0.01
    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # Hybrid (Zamba2): one shared attention block every ``hybrid_period``
    # Mamba2 layers (weights shared across invocations).
    hybrid_period: int = 0
    # Encoder-decoder (Whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500
    use_rope: bool = True
    learned_pos: int = 0  # >0: learned absolute positions (clamped table)
    # Early fusion (Pixtral / Llama4): precomputed patch embeddings replace
    # the first ``n_image_patches`` positions (frontend stub).
    n_image_patches: int = 0
    # Infra
    vocab_pad_multiple: int = 128
    scan_layers: bool = True
    remat: str = "full"  # none | full | dots
    attention_impl: str = "xla"  # xla | xla_skip | pallas
    sequence_parallel: bool = False
    attn_q_block: int = 512
    attn_kv_block: int = 512
    attn_probs_bf16: bool = False  # bf16 PV matmul (Perf lever)
    grad_accum: int = 1  # microbatch count (memory-capacity lever)
    # Which assigned shape cells apply (long_500k only for sub-quadratic).
    skip_shapes: tuple[str, ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return (
            self.head_dim
            if self.head_dim is not None
            else self.d_model // self.n_heads
        )

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return math.ceil(self.vocab_size / m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def shapes(self) -> tuple[ShapeCell, ...]:
        return tuple(c for c in SHAPES if c.name not in self.skip_shapes)
