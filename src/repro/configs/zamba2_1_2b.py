"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=32000, ssm_state=64 -- Mamba2 stack + a SHARED attention block
(one set of weights) applied every 6 Mamba2 layers.
[arXiv:2411.15242; hf]

Layout here: 6 groups of (6 Mamba2 layers + shared attn/FFN block) + 2
trailing Mamba2 layers = 38 Mamba2 layers, 6 shared-block invocations
(each invocation keeps its own KV cache).  Hybrid => ``long_500k`` runs;
the shared-block KV cache for the 500k cell is sharded over the data
axis (kv_seq rule).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_1_2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_period=6,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    ssm_state=16,
    ssm_head_dim=16,
    hybrid_period=2,
    vocab_size=256,
    vocab_pad_multiple=8,
    ssm_chunk=16,
    attn_q_block=32,
    attn_kv_block=32,
)
