"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Exact assigned configs live in one module per architecture
(``repro.configs.<id>``); ``smoke_config(name)`` returns the reduced
same-family variant used by CPU smoke tests.
"""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

ARCH_IDS: tuple[str, ...] = (
    "qwen3_4b",
    "gemma_2b",
    "qwen2_1_5b",
    "h2o_danube3_4b",
    "mamba2_130m",
    "zamba2_1_2b",
    "pixtral_12b",
    "qwen2_moe_a2_7b",
    "llama4_scout_17b_16e",
    "whisper_small",
)

_ALIASES = {
    "qwen3-4b": "qwen3_4b",
    "gemma-2b": "gemma_2b",
    "qwen2-1.5b": "qwen2_1_5b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "mamba2-130m": "mamba2_130m",
    "zamba2-1.2b": "zamba2_1_2b",
    "pixtral-12b": "pixtral_12b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "whisper-small": "whisper_small",
}


def canonical(name: str) -> str:
    name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return name


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE


def all_configs() -> dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCH_IDS}
