"""Deterministic, resumable synthetic token pipeline.

Stateless generation: batch ``i`` is a pure function of (seed, i) via
``jax.random.fold_in``, so the iterator state is a single integer --
checkpoints store it and resume exactly (bitwise) after restarts or
elastic re-meshing.  Batches are placed on the mesh with the rules
engine's batch sharding.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.sharding.rules import MeshContext


@dataclasses.dataclass
class SyntheticPipeline:
    cfg: ArchConfig
    cell: ShapeCell
    seed: int = 0
    index: int = 0  # next batch index (the full resumable state)

    def state(self) -> dict:
        return {"seed": self.seed, "index": self.index}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.index = int(state["index"])

    def _batch_at(self, i: int) -> dict:
        import numpy as np

        cfg, cell = self.cfg, self.cell
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), i)
        b, s = cell.global_batch, cell.seq_len
        kt, kx = jax.random.split(key)
        # Learnable synthetic language: an affine next-token recurrence
        # t_{i+1} = (a * t_i + c) mod (V-1) + 1 with random starts -- the
        # next token is a deterministic function of the current one, so
        # the loss floor is ~0 and training curves are meaningful.
        m = cfg.vocab_size - 1
        a, c = 5 % m or 1, 7 % m
        start = np.asarray(
            jax.random.randint(kt, (b,), 1, cfg.vocab_size), np.int64
        )
        stream = np.empty((b, s + 1), np.int64)
        stream[:, 0] = start
        cur = start - 1
        for t in range(1, s + 1):
            cur = (a * cur + c) % m
            stream[:, t] = cur + 1
        batch = {
            "tokens": jnp.asarray(stream[:, :-1], jnp.int32),
            "targets": jnp.asarray(stream[:, 1:], jnp.int32),
        }
        if cfg.n_image_patches and cfg.family in ("vlm", "moe"):
            batch["image_embeds"] = jax.random.normal(
                kx, (b, cfg.n_image_patches, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "audio":
            batch["encoder_frames"] = jax.random.normal(
                kx, (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16
            )
        return batch

    def __next__(self) -> dict:
        batch = self._batch_at(self.index)
        self.index += 1
        return batch

    def __iter__(self):
        return self


def shard_batch(batch: dict, ctx: MeshContext) -> dict:
    """Place a host batch on the mesh (batch dim over the dp axes)."""
    out = {}
    for name, value in batch.items():
        axes: tuple = ("batch",) + (None,) * (value.ndim - 1)
        out[name] = jax.device_put(
            value, ctx.sharding_for(value.shape, axes)
        )
    return out
