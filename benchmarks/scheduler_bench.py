"""Scheduler solve-time benchmark (paper: "practical solve times under
90 seconds per collective at 128 nodes" with Gurobi).

Reports SWOT scheduling time per collective instance for the greedy+LP
path (used at scale) and the exact MILP on small instances.
"""

import time

from repro.core import (
    OpticalFabric,
    get_pattern,
    prestage_for,
    solve_milp,
    swot_greedy,
)


def run() -> list[tuple[str, float, str]]:
    rows = []
    for algorithm, n in (
        ("rabenseifner_allreduce", 32),
        ("rabenseifner_allreduce", 128),
        ("rabenseifner_allreduce", 512),
        ("pairwise_alltoall", 32),
        ("bruck_alltoall", 128),
    ):
        pattern = get_pattern(algorithm, n, 40e6)
        fabric = prestage_for(OpticalFabric(n, 4), pattern)
        t0 = time.perf_counter()
        sched = swot_greedy(fabric, pattern)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            (
                f"sched_greedy_{algorithm}_n{n}",
                us,
                f"cct={sched.cct * 1e6:.1f}us steps={pattern.n_steps} "
                f"(paper Gurobi: <90s at n=128)",
            )
        )
    # Exact MILP reference on a small instance.
    pattern = get_pattern("bruck_alltoall", 32, 40e6)
    fabric = prestage_for(OpticalFabric(32, 4), pattern)
    t0 = time.perf_counter()
    res = solve_milp(fabric, pattern)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        (
            "sched_milp_bruck_n32",
            us,
            f"cct={res.schedule.cct * 1e6:.1f}us gap={res.mip_gap:.1e}",
        )
    )
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
