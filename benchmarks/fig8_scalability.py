"""Paper Fig. 8: CCT vs cluster size (4 OCS planes, 40 MB collective).

* Rabenseifner AllReduce, 8..512 nodes -- one-shot becomes infeasible
  beyond 16 nodes (> 4 distinct configs on 4 planes), matching the paper;
  the SWOT-vs-strawman reduction must GROW with cluster size (paper:
  14.5% at 64 -> 35.2% at 512).
* Pairwise All-to-All, 4..10 nodes -- one-shot infeasible beyond 5 nodes;
  SWOT-vs-strawman gain grows (paper: 20.0% at 5 -> 42.6% at 10).
"""

from repro.core import (
    OpticalFabric,
    get_pattern,
    plan_collective,
    prestage_for,
)

SIZE = 40e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    for algorithm, nodes in (
        ("rabenseifner_allreduce", (8, 16, 32, 64, 128, 256, 512)),
        ("pairwise_alltoall", (4, 5, 6, 8, 10)),
    ):
        for n in nodes:
            pattern = get_pattern(algorithm, n, SIZE)
            fabric = prestage_for(OpticalFabric(n, 4), pattern)
            plan = plan_collective(
                fabric, pattern, milp_time_limit=10.0
            )
            oneshot = (
                f"{plan.one_shot_cct * 1e6:.1f}us"
                if plan.one_shot_cct is not None
                else "infeasible"
            )
            rows.append(
                (
                    f"fig8_{algorithm}_n{n}",
                    plan.cct * 1e6,
                    f"strawman={plan.strawman_cct * 1e6:.1f}us "
                    f"oneshot={oneshot} "
                    f"vs_strawman={plan.vs_strawman:+.1%} "
                    f"method={plan.method}",
                )
            )
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
