"""Batched scenario-sweep benchmark: array IR vs per-instance object path.

Two sweeps, two acceptance gates:

* ``run`` -- the historical 64-instance sweep (8 message sizes x 8
  reconfiguration delays of strawman-ICR Rabenseifner AllReduce on
  8 nodes x 4 planes), evaluated per instance through the *historical*
  object pipeline (`repro.core.simulator.execute` building
  ``PlaneActivity`` objects, validated with the interpreted
  ``validate_object`` oracle) and in ONE `repro.core.ir.batch_evaluate`
  pass.  Per-instance CCTs must agree within 1e-9 and the batched pass
  must be >= 5x faster (gated for the default numpy backend; pass
  ``--backend jax|pallas`` to time an accelerator backend instead --
  parity still asserted).
* ``backend_throughput`` -- the LARGE grid (32 sizes x 32 delays of
  128-node pairwise all-to-all, 127 steps): one packed batch evaluated by
  every available timing backend, with cold (first call: trace+compile)
  and warm timings reported separately (``compile_ms`` is an ungated
  wall-clock row; the gate only sees warm numbers).  The jax backend
  must be >= 2x faster than the numpy reference on this grid (CPU jit
  counts); the Pallas backend runs in interpret mode for functional
  parity only (its wall time on CPU is the interpreter's, not the
  kernel's) -- a compiled-mode (``interpret=False``) probe runs once and
  its outcome is recorded in the payload, so the kernel's reference-only
  status on CPU-only hosts is a measurement, not an assumption.
  ``run.py`` dumps these numbers to ``BENCH_backends.json`` for the
  cross-PR perf trajectory.

A fifth gate, ``fused_grid``, times the fused on-device CHAIN planner
(`repro.core.ir.fused`: the whole greedy loop as ONE jitted
``lax.scan``) against the per-step numpy loop on the same 1024-cell
grid (``max_enumerated_planes=4`` so the reserve sets are the dynamic
soonest-free rows, the at-scale configuration).  The fused warm time
must be >= 2x faster with bitwise-identical chosen splits (0 mismatched
cells, asserted in-run).  Cold (trace+compile) time is reported
ungated.

A third gate rides along: ``independent_grid`` plans a 16 x 16 grid of
64-node pairwise all-to-all cells with the instance-batched
INDEPENDENT-mode greedy (``swot_greedy_grid(mode=INDEPENDENT)``) and
must be >= 2x faster than the per-instance ``independent_decisions``
loop -- with bitwise-identical decisions.  Its numbers land in both
``BENCH_sweep.json`` (as ``run`` rows) and ``BENCH_backends.json``.

A fourth section, ``bypass_sweep``, gates Topology Bypassing: the
bypass-enabled grid greedy (``swot_greedy_grid(bypass_depth=2)``) must
STRICTLY reduce CCT vs the no-bypass greedy at the documented
high-``t_recfg`` point (pre-staged 8-node pairwise all-to-all on 4
planes, ``t_recfg`` = 3.2 ms), every bypass schedule must pass
``validate_ir``, and grid CCTs must match the object executor bitwise.
The per-point CCTs and bypass/no-bypass ratios are deterministic
``BENCH_sweep.json`` rows, so the regression gate pins the reduction.
"""

import argparse
import time

import numpy as np

from repro.core import (
    BatchInstance,
    OpticalFabric,
    batch_evaluate,
    independent_decisions,
    pairwise_alltoall,
    rabenseifner_allreduce,
    strawman_instance,
    swot_greedy_grid,
)
from repro.core.ir import BackendUnavailable, get_backend, resolve_backend
from repro.core.ir.engine import pack_instances
from repro.core.schedule import DependencyMode, Kind, validate_object
from repro.core.simulator import execute
from repro.obs import attribute


def _object_path_cct(inst: BatchInstance) -> float:
    """The pre-IR per-instance pipeline: build objects, validate, read CCT."""
    schedule = execute(
        inst.fabric, inst.pattern, inst.decisions, validate=False
    )
    validate_object(schedule)
    return schedule.cct

_N_NODES = 8
_N_PLANES = 4
_SIZES = tuple(2**i * 1e6 for i in range(8))  # 1 .. 128 MB
_RECFGS = tuple(25e-6 * 2**i for i in range(8))  # 25 us .. 3.2 ms


def _instances() -> list[BatchInstance]:
    return [
        strawman_instance(
            OpticalFabric(_N_NODES, _N_PLANES, t_recfg=t_recfg),
            rabenseifner_allreduce(_N_NODES, size),
            prestage=True,
        )
        for size in _SIZES
        for t_recfg in _RECFGS
    ]


def run(
    quick: bool = False, backend: str | None = None
) -> list[tuple[str, float, str]]:
    del quick  # the 64-cell sweep IS the CI smoke test
    # Resolve now so the row tag and the numpy-only gate reflect what is
    # actually timed (backend=None follows REPRO_IR_BACKEND).
    backend = resolve_backend(backend).name
    instances = _instances()
    n = len(instances)
    # Best-of-3 on both sides: one-shot timings are too noisy for a CI
    # gate (first-call numpy warm-up, scheduler jitter).
    t_object = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        object_cct = np.array([_object_path_cct(i) for i in instances])
        t_object = min(t_object, time.perf_counter() - t0)
    batch_evaluate(instances, backend=backend)  # warm (jit compiles here)
    t_batch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        result = batch_evaluate(instances, backend=backend)
        t_batch = min(t_batch, time.perf_counter() - t0)
    err = float(np.max(np.abs(result.cct - object_cct)))
    assert err <= 1e-9, f"batched CCT diverges from object path by {err}"
    speedup = t_object / t_batch
    # The >= 5x gate pins the refactor payoff for the deterministic
    # default; accelerator backends are gated on the large grid instead
    # (64 cells cannot amortize a device round trip).
    if backend == "numpy":
        assert speedup >= 5.0, (
            f"batched IR sweep only {speedup:.1f}x faster than the "
            "per-instance object path (acceptance gate is >= 5x)"
        )
    tag = backend
    return [
        (
            "ir_sweep_object_path",
            t_object * 1e6 / n,
            f"{n} instances total={t_object * 1e3:.1f}ms",
        ),
        (
            f"ir_sweep_batched_{tag}",
            t_batch * 1e6 / n,
            f"speedup={speedup:.1f}x max_cct_err={err:.1e}",
        ),
    ] + independent_grid_rows() + bypass_rows() + attribution_rows()


# INDEPENDENT-mode grid: 16 sizes x 16 delays of 64-node pairwise
# all-to-all (63 steps each).  Deep enough in steps that the
# per-instance argmin-packing loop's Python turns dominate, small
# enough (~0.2 s per rep) for the CI smoke sweep.
_INDEP_NODES = 64
_INDEP_PLANES = 8
_INDEP_SIZES = tuple(1e6 * (1 + i) for i in range(16))
_INDEP_RECFGS = tuple(25e-6 * (1 + i) for i in range(16))

_independent_grid_cache: dict | None = None


def independent_grid(quick: bool = False) -> dict:
    """Instance-batched INDEPENDENT grid vs the per-instance loop.

    Both sides produce scored plans for every cell: the per-instance
    path runs ``independent_decisions`` per cell plus one
    ``batch_evaluate`` scoring pass; the batched path is ONE
    ``swot_greedy_grid(mode=INDEPENDENT)`` call.  Decisions must be
    bitwise identical and the batched path >= 2x faster (the
    acceptance gate for batching the last per-step Python out of the
    grid path).  The payload is memoized so ``run.py`` can record it
    in both BENCH JSON files without re-timing.
    """
    global _independent_grid_cache
    del quick  # the grid must stay step-deep or the gate is meaningless
    if _independent_grid_cache is not None:
        return _independent_grid_cache
    patterns = {
        size: pairwise_alltoall(_INDEP_NODES, size)
        for size in _INDEP_SIZES
    }
    cells = [
        (
            OpticalFabric(_INDEP_NODES, _INDEP_PLANES, t_recfg=t_recfg),
            patterns[size],
        )
        for size in _INDEP_SIZES
        for t_recfg in _INDEP_RECFGS
    ]
    t_instance = t_grid = float("inf")
    # Interleave best-of-3 reps so host load spikes skew both sides alike.
    for _ in range(3):
        t0 = time.perf_counter()
        decisions = [
            independent_decisions(fabric, pattern)
            for fabric, pattern in cells
        ]
        batch_evaluate(
            [
                BatchInstance(fabric, pattern, dec)
                for (fabric, pattern), dec in zip(cells, decisions)
            ]
        )
        t_instance = min(t_instance, time.perf_counter() - t0)
        t0 = time.perf_counter()
        plans = swot_greedy_grid(cells, mode=DependencyMode.INDEPENDENT)
        t_grid = min(t_grid, time.perf_counter() - t0)
    mismatches = sum(
        plan.decisions != dec for plan, dec in zip(plans, decisions)
    )
    assert mismatches == 0, (
        f"INDEPENDENT grid decisions diverge from per-instance "
        f"independent_decisions on {mismatches}/{len(cells)} cells"
    )
    speedup = t_instance / t_grid
    assert speedup >= 2.0, (
        f"INDEPENDENT grid greedy only {speedup:.1f}x faster than the "
        "per-instance path (acceptance gate is >= 2x)"
    )
    _independent_grid_cache = {
        "cells": len(cells),
        "pattern": f"pairwise_alltoall_{_INDEP_NODES}",
        "n_steps": cells[0][1].n_steps,
        "n_planes": _INDEP_PLANES,
        "per_instance_ms": round(t_instance * 1e3, 3),
        "grid_ms": round(t_grid * 1e3, 3),
        "us_per_instance": round(t_grid * 1e6 / len(cells), 3),
        "speedup_vs_per_instance": round(speedup, 2),
        "decision_mismatches": mismatches,
    }
    return _independent_grid_cache


def independent_grid_rows(
    quick: bool = False,
) -> list[tuple[str, float, str]]:
    """``independent_grid`` reshaped into benchmark CSV rows."""
    g = independent_grid(quick=quick)
    return [
        (
            "indep_grid_per_instance",
            g["per_instance_ms"] * 1e3 / g["cells"],
            f"{g['cells']} cells total={g['per_instance_ms']:.1f}ms",
        ),
        (
            "indep_grid_batched",
            g["us_per_instance"],
            f"speedup={g['speedup_vs_per_instance']}x "
            f"mismatches={g['decision_mismatches']}",
        ),
    ]


# Topology Bypassing sweep: pre-staged 8-node pairwise all-to-all on 4
# planes (rotation configs, so the pre-staged rot(1) circuit self-relays
# to rot(2) in 2 hops) across the t_recfg axis.  In the high-t_recfg
# regime relays dominate reconfiguration; the documented 3.2 ms point
# must show a strict >= 25% CCT reduction (observed ~47%).
_BYPASS_NODES = 8
_BYPASS_PLANES = 4
_BYPASS_SIZE = 8e6
_BYPASS_RECFGS = (2e-4, 8e-4, 3.2e-3)
_BYPASS_DEPTH = 2
_BYPASS_GATE_RECFG = 3.2e-3
_BYPASS_GATE_REDUCTION = 0.25


def bypass_sweep(quick: bool = False) -> list[tuple[str, float, str]]:
    """Bypass-enabled vs no-bypass grid greedy on the t_recfg axis.

    Deterministic CCT rows (simulated quantities -- identical on any
    machine, so the regression gate holds them to the 25% band) plus the
    bypass/no-bypass CCT ratio per point.  Asserts in-run: every bypass
    schedule passes ``validate_ir`` with object-path-bitwise CCT, bypass
    never loses (the guarded pick), and the documented high-t_recfg
    point strictly reduces CCT by the gate margin.
    """
    del quick  # 3 cells; the sweep IS the CI smoke test
    pattern = pairwise_alltoall(_BYPASS_NODES, _BYPASS_SIZE)
    cells = []
    for t_recfg in _BYPASS_RECFGS:
        fabric = OpticalFabric(
            _BYPASS_NODES, _BYPASS_PLANES, t_recfg=t_recfg
        ).prestaged(pattern.steps[0].config)
        cells.append((fabric, pattern))
    base = swot_greedy_grid(cells, backend="numpy")
    byp = swot_greedy_grid(
        cells, backend="numpy", bypass_depth=_BYPASS_DEPTH
    )
    # Every available accelerator backend must reproduce the numpy CCTs
    # bitwise on this bypass batch (relay routes + fractional-bandwidth
    # splits): the pallas kernel handles bypass natively now, so this
    # in-run check keeps the no-numpy-delegation contract measured, not
    # assumed.
    byp_insts = [
        BatchInstance(fabric, pattern, y.decisions)
        for (fabric, pattern), y in zip(cells, byp)
    ]
    ref = batch_evaluate(byp_insts, backend="numpy")
    for name in ("jax", "pallas"):
        try:
            get_backend(name)
        except BackendUnavailable:
            continue
        got = batch_evaluate(byp_insts, backend=name)
        assert np.array_equal(got.cct, ref.cct), (
            f"{name} backend CCT diverges from numpy on bypass batch"
        )
    rows = []
    for (fabric, _), b, y in zip(cells, base, byp):
        # Legality + object-path parity for every bypass schedule.
        schedule = y.schedule()  # execute() validates (P1-P4)
        assert schedule.cct == y.cct, "IR/object CCT parity broken"
        assert y.cct <= b.cct + 1e-12, "guarded bypass pick regressed CCT"
        t_us = fabric.t_recfg * 1e6
        label = f"bypass_pairwise{_BYPASS_NODES}x{_BYPASS_PLANES}"
        rows.append(
            (
                f"{label}_t{t_us:.0f}_nobypass_cct",
                b.cct * 1e6,
                f"t_recfg={t_us:.0f}us",
            )
        )
        rows.append(
            (
                f"{label}_t{t_us:.0f}_depth{_BYPASS_DEPTH}_cct",
                y.cct * 1e6,
                f"reduction={1 - y.cct / b.cct:.1%}",
            )
        )
        rows.append(
            (
                f"{label}_t{t_us:.0f}_cct_ratio",
                y.cct / b.cct,
                "bypass/no-bypass (<= 1 by the guarded pick)",
            )
        )
        if fabric.t_recfg == _BYPASS_GATE_RECFG:
            assert y.cct < b.cct * (1.0 - _BYPASS_GATE_REDUCTION), (
                f"bypass reduction only {1 - y.cct / b.cct:.1%} at "
                f"t_recfg={t_us:.0f}us (acceptance gate is "
                f">= {_BYPASS_GATE_REDUCTION:.0%} strict)"
            )
            n_relays = sum(
                1 for a in schedule.activities if a.route >= 0
            )
            assert n_relays > 0, "gate point used no relays"
            # Bypass hit rate: of the steps that needed a circuit
            # change, the fraction served by relaying over installed
            # circuits instead of reconfiguring.  Deterministic and
            # gated HIGHER-is-better by check_regression.
            relay_steps = {
                a.step for a in schedule.activities if a.route >= 0
            }
            recfg_steps = {
                a.step
                for a in schedule.activities
                if a.kind is Kind.RECFG
            }
            denom = len(relay_steps | recfg_steps)
            rows.append(
                (
                    f"{label}_t{t_us:.0f}_bypass_hit_rate",
                    len(relay_steps) / denom if denom else 0.0,
                    f"{len(relay_steps)} relay vs {len(recfg_steps)} "
                    "reconfig steps",
                )
            )
    return rows


# Back-compat friendly alias used by ``run``.
bypass_rows = bypass_sweep


# CCT-attribution sweep: overlap efficiency of the greedy plans across
# the t_recfg axis for the two headline algorithms.  Simulated
# quantities (deterministic on any machine), gated HIGHER-is-better by
# check_regression: an overlap-efficiency drop past the band means a
# scheduler change stopped hiding reconfigurations it used to hide.
_ATTR_NODES = 8
_ATTR_PLANES = 4
_ATTR_SIZE = 8e6
_ATTR_RECFGS = (50e-6, 200e-6, 3.2e-3)
_ATTR_ALGS = (
    ("rab", rabenseifner_allreduce),
    ("pw", pairwise_alltoall),
)


def attribution_rows(quick: bool = False) -> list[tuple[str, float, str]]:
    """Overlap-efficiency rows from attributed greedy plans.

    One ``swot_greedy_grid`` pass plans every cell; every available
    timing backend then re-evaluates the batch with
    ``attribution=True``.  In-run gates: components must sum *bitwise*
    to the CCT on every backend, efficiencies must agree across
    backends within 1e-9, and the object-walk oracle
    (``repro.obs.attribute`` over ``execute``) must agree per cell.
    """
    del quick  # 6 cells; the sweep IS the CI smoke test
    cells = []
    labels = []
    for tag, make in _ATTR_ALGS:
        pattern = make(_ATTR_NODES, _ATTR_SIZE)
        for t_recfg in _ATTR_RECFGS:
            fabric = OpticalFabric(
                _ATTR_NODES, _ATTR_PLANES, t_recfg=t_recfg
            ).prestaged(pattern.steps[0].config)
            cells.append((fabric, pattern))
            labels.append(
                f"attr_{tag}{_ATTR_NODES}x{_ATTR_PLANES}"
                f"_t{t_recfg * 1e6:.0f}_overlap_eff"
            )
    plans = swot_greedy_grid(cells, backend="numpy")
    instances = [
        BatchInstance(p.fabric, p.pattern, p.decisions) for p in plans
    ]
    eff = hidden = exposed = None
    for name in ("numpy", "jax", "pallas"):
        try:
            get_backend(name)
        except BackendUnavailable:
            continue
        result = batch_evaluate(instances, backend=name, attribution=True)
        att = result.attribution
        total = np.where(att.plane_mask, att.plane_total, 0.0)
        want = np.where(att.plane_mask, result.cct[:, None], 0.0)
        assert np.array_equal(total, want), (
            f"{name} attribution components do not sum bitwise to CCT"
        )
        if eff is None:
            eff = att.overlap_efficiency
            hidden, exposed = att.hidden_recfg, att.exposed_recfg
        else:
            err = float(np.max(np.abs(att.overlap_efficiency - eff)))
            assert err <= 1e-9, (
                f"{name} overlap efficiency diverges from numpy by {err}"
            )
    assert eff is not None
    rows = []
    for label, inst, plan, e, h, x in zip(
        labels, instances, plans, eff, hidden, exposed
    ):
        # Object-walk oracle parity per cell.
        schedule = execute(
            inst.fabric, inst.pattern, inst.decisions, validate=False
        )
        oracle = attribute(schedule)
        o_eff = float(oracle.overlap_efficiency)
        assert abs(o_eff - float(e)) <= 1e-9, (
            f"{label}: object-walk efficiency {o_eff} vs batched {e}"
        )
        rows.append(
            (
                label,
                float(e),
                f"hidden={float(h) * 1e6:.1f}us "
                f"exposed={float(x) * 1e6:.1f}us "
                f"cct={plan.cct * 1e6:.1f}us",
            )
        )
    return rows


# Large grid: 32 sizes x 32 delays of 128-node pairwise all-to-all
# (127 steps) = 1024 cells.  Deep enough in steps that the numpy path's
# per-step Python turns dominate while the jax scan stays one compiled
# program (~3.2x observed unloaded, higher under CPU contention, vs the
# 2x gate); small enough to build in a few seconds.
_GRID_NODES = 128
_GRID_PLANES = 8
_GRID_SIZES = tuple(1e6 * (1 + i) for i in range(32))
_GRID_RECFGS = tuple(12.5e-6 * (1 + i) for i in range(32))


def backend_throughput(quick: bool = False) -> dict:
    """Time every available backend on one packed large-grid batch.

    Returns a JSON-ready payload (``run.py`` writes it to
    ``BENCH_backends.json``); asserts the jax backend is >= 2x the numpy
    reference on this grid whenever jax is importable.  The first call
    per backend is timed separately as ``cold_ms`` (trace + jit compile
    + first run) and ``compile_ms`` (cold minus warm best) -- ungated
    wall-clock rows, so compile latency is tracked without contaminating
    the warm-throughput gate.
    """
    del quick  # the grid must stay large or the 2x gate is meaningless
    instances = [
        strawman_instance(
            OpticalFabric(_GRID_NODES, _GRID_PLANES, t_recfg=t_recfg),
            pairwise_alltoall(_GRID_NODES, size),
            prestage=True,
        )
        for size in _GRID_SIZES
        for t_recfg in _GRID_RECFGS
    ]
    packed = pack_instances(instances, None)
    ref_cct: np.ndarray | None = None
    payload: dict = {
        "grid": {
            "cells": len(instances),
            "pattern": f"pairwise_alltoall_{_GRID_NODES}",
            "n_steps": instances[0].pattern.n_steps,
            "n_planes": _GRID_PLANES,
        },
        "backends": {},
    }
    engines = {}
    for name in ("numpy", "jax", "pallas"):
        try:
            engines[name] = get_backend(name)
        except BackendUnavailable as exc:
            payload["backends"][name] = {"unavailable": str(exc)}
    best = {name: float("inf") for name in engines}
    cold = {}
    results = {}
    for name, engine in engines.items():
        # Cold = trace + compile + first execution (numpy's is just its
        # first-touch warm-up; still reported for symmetry).
        t0 = time.perf_counter()
        results[name] = engine.derive_timing(packed)
        cold[name] = time.perf_counter() - t0
    # Interleave the timed reps across backends so a load spike on the
    # host (CI runners are shared) skews every backend alike instead of
    # flipping the gated ratio.
    for rep in range(5):
        for name, engine in engines.items():
            if name == "pallas" and rep >= 2:
                continue  # interpret mode is slow; 2 reps suffice
            t0 = time.perf_counter()
            results[name] = engine.derive_timing(packed)
            best[name] = min(best[name], time.perf_counter() - t0)
    for name in engines:
        result = results[name]
        if ref_cct is None:
            ref_cct = result.cct
            err = 0.0
        else:
            err = float(np.max(np.abs(result.cct - ref_cct)))
            assert err <= 1e-9, (
                f"{name} backend CCT diverges from numpy by {err}"
            )
        payload["backends"][name] = {
            "ms": round(best[name] * 1e3, 3),
            "cold_ms": round(cold[name] * 1e3, 3),
            "compile_ms": round(
                max(0.0, cold[name] - best[name]) * 1e3, 3
            ),
            "us_per_instance": round(
                best[name] * 1e6 / len(instances), 3
            ),
            "max_cct_err_vs_numpy": err,
        }
    np_ms = payload["backends"]["numpy"]["ms"]
    for name, entry in payload["backends"].items():
        if "ms" in entry:
            entry["speedup_vs_numpy"] = round(np_ms / entry["ms"], 2)
    jax_entry = payload["backends"]["jax"]
    if "ms" in jax_entry:
        assert jax_entry["speedup_vs_numpy"] >= 2.0, (
            f"jax backend only {jax_entry['speedup_vs_numpy']}x vs numpy "
            "on the large grid (acceptance gate is >= 2x)"
        )
    # Compiled-pallas probe: interpret=False compiles the actual Mosaic/
    # Triton kernel, which needs a TPU/GPU backend.  On CPU-only hosts
    # the attempt fails; the failure string is recorded so the kernel's
    # reference-only status (DESIGN.md section 17) stays a measurement.
    payload["pallas_compiled"] = _pallas_compiled_probe()
    # The INDEPENDENT-mode grid gate rides along in the same payload so
    # BENCH_backends.json tracks both batching trajectories per PR,
    # as does the fused on-device planner gate.
    payload["independent_grid"] = independent_grid()
    payload["fused_grid"] = fused_grid()
    return payload


def _pallas_compiled_probe() -> dict:
    """Try the pallas kernel with ``interpret=False`` on a small batch.

    Succeeds only where pallas can lower for the local accelerator
    (TPU/GPU).  On CPU-only hosts this records the failure string --
    the documented basis for keeping the kernel at reference status
    until accelerator CI exists.
    """
    from repro.core.ir.backends import PallasBackend

    probe = [
        strawman_instance(
            OpticalFabric(8, 4, t_recfg=25e-6),
            pairwise_alltoall(8, 1e6),
            prestage=True,
        )
    ]
    try:
        backend = PallasBackend(interpret=False)
    except BackendUnavailable as exc:
        return {"available": False, "error": str(exc)}
    try:
        packed = pack_instances(probe, None)
        backend.derive_timing(packed)  # compile + run
        t0 = time.perf_counter()
        result = backend.derive_timing(packed)
        warm_ms = (time.perf_counter() - t0) * 1e3
    except Exception as exc:  # lowering fails off-accelerator
        return {"available": False, "error": f"{type(exc).__name__}: {exc}"}
    ref = get_backend("numpy").derive_timing(pack_instances(probe, None))
    err = float(np.max(np.abs(result.cct - ref.cct)))
    return {
        "available": True,
        "warm_ms": round(warm_ms, 3),
        "max_cct_err_vs_numpy": err,
    }


# Fused-planner gate: same 1024-cell grid as ``backend_throughput`` but
# timing the CHAIN *planner* loops themselves (candidate construction,
# water-fill, rollout, selection) rather than the timing recurrence.
# ``max_enumerated_planes=4`` keeps every cell on the dynamic
# soonest-free reserve rows -- the at-scale configuration, and the one
# where the per-step loop's per-step Python cost is honest (8 planes
# enumerated would mean 247 static rows per cell and minutes per rep).
_FUSED_ENUM_PLANES = 4
_FUSED_HORIZON = 24

_fused_grid_cache: dict | None = None


def fused_grid(quick: bool = False) -> dict:
    """Fused ``lax.scan`` CHAIN planner vs the per-step numpy loop.

    Both sides plan the identical 1024-cell grid from identical fresh
    ``_GridState``s (state build excluded from both timings -- it is
    shared setup, not planner work).  Asserts in-run: the fused
    planner's chosen splits are bitwise-identical to the per-step
    loop's on every cell (0 mismatches), and the fused *warm* time
    beats the per-step loop by >= 2x (the perf-optimization acceptance
    gate).  Cold time (trace + XLA compile + first run) is reported
    ungated.  Memoized so ``run.py`` records it without re-timing.
    """
    global _fused_grid_cache
    del quick  # the grid must stay step-deep or the gate is meaningless
    if _fused_grid_cache is not None:
        return _fused_grid_cache
    from repro.core import greedy as _greedy
    from repro.core.ir.fused import fused_chain_grid_chosen

    patterns = {
        size: pairwise_alltoall(_GRID_NODES, size) for size in _GRID_SIZES
    }
    cells = [
        (
            OpticalFabric(_GRID_NODES, _GRID_PLANES, t_recfg=t_recfg),
            patterns[size],
        )
        for size in _GRID_SIZES
        for t_recfg in _GRID_RECFGS
    ]

    def mk_state() -> "_greedy._GridState":
        return _greedy._GridState(
            cells,
            mode=DependencyMode.CHAIN,
            max_enumerated_planes=_FUSED_ENUM_PLANES,
        )

    # Planners mutate their state, so each timed run gets a fresh one.
    # Cold first: the one-time trace+compile of the scan.
    st = mk_state()
    t0 = time.perf_counter()
    fused_chosen = fused_chain_grid_chosen(st, _FUSED_HORIZON)
    t_cold = time.perf_counter() - t0
    t_fused = float("inf")
    for _ in range(2):
        st = mk_state()
        t0 = time.perf_counter()
        fused_chosen = fused_chain_grid_chosen(st, _FUSED_HORIZON)
        t_fused = min(t_fused, time.perf_counter() - t0)
    st = mk_state()
    t0 = time.perf_counter()
    step_chosen = _greedy._chain_grid_chosen(st, _FUSED_HORIZON)
    t_step = time.perf_counter() - t0
    # Decisions parity, cell-resolution: a mismatched cell is one whose
    # chosen split or bypass-hop row differs at any step.
    assert len(step_chosen) == len(fused_chosen), "planner step counts"
    bad_cells: set[int] = set()
    for (rows_s, split_s, byp_s), (rows_f, split_f, byp_f) in zip(
        step_chosen, fused_chosen
    ):
        assert np.array_equal(rows_s, rows_f), "live-row sets diverge"
        bad = (split_s != split_f).any(axis=1) | (byp_s != byp_f).any(
            axis=1
        )
        bad_cells.update(int(c) for c in rows_s[bad])
    mismatches = len(bad_cells)
    assert mismatches == 0, (
        f"fused planner decisions diverge from the per-step loop on "
        f"{mismatches}/{len(cells)} cells"
    )
    speedup = t_step / t_fused
    assert speedup >= 2.0, (
        f"fused planner only {speedup:.1f}x faster than the per-step "
        "loop on the large grid (acceptance gate is >= 2x warm)"
    )
    _fused_grid_cache = {
        "cells": len(cells),
        "pattern": f"pairwise_alltoall_{_GRID_NODES}",
        "n_steps": cells[0][1].n_steps,
        "n_planes": _GRID_PLANES,
        "max_enumerated_planes": _FUSED_ENUM_PLANES,
        "rollout_horizon": _FUSED_HORIZON,
        "per_step_ms": round(t_step * 1e3, 3),
        "fused_cold_ms": round(t_cold * 1e3, 3),
        "fused_warm_ms": round(t_fused * 1e3, 3),
        "us_per_cell": round(t_fused * 1e6 / len(cells), 3),
        "speedup_vs_per_step": round(speedup, 2),
        "decision_mismatches": mismatches,
    }
    return _fused_grid_cache


def backend_rows(quick: bool = False) -> list[tuple[str, float, str]]:
    """``backend_throughput`` reshaped into benchmark CSV rows.

    All row names carry a wall-clock prefix (``ir_backend_`` /
    ``fused_grid_``) so ``check_regression`` excludes the absolute
    microseconds; only the payload's speedup *ratios* are gated.
    """
    payload = backend_throughput(quick=quick)
    cells = payload["grid"]["cells"]
    rows = []
    for name, entry in payload["backends"].items():
        if "ms" not in entry:
            rows.append((f"ir_backend_{name}", 0.0, "unavailable"))
            continue
        rows.append(
            (
                f"ir_backend_{name}",
                entry["us_per_instance"],
                f"{cells} cells total={entry['ms']:.1f}ms "
                f"speedup={entry['speedup_vs_numpy']}x",
            )
        )
        rows.append(
            (
                f"ir_backend_{name}_compile",
                entry["compile_ms"] * 1e3,
                f"cold={entry['cold_ms']:.1f}ms warm={entry['ms']:.1f}ms",
            )
        )
    g = payload["fused_grid"]
    rows.append(
        (
            "fused_grid_per_step",
            g["per_step_ms"] * 1e3 / g["cells"],
            f"{g['cells']} cells total={g['per_step_ms']:.1f}ms",
        )
    )
    rows.append(
        (
            "fused_grid_batched",
            g["us_per_cell"],
            f"speedup={g['speedup_vs_per_step']}x "
            f"mismatches={g['decision_mismatches']}",
        )
    )
    rows.append(
        (
            "fused_grid_compile",
            (g["fused_cold_ms"] - g["fused_warm_ms"]) * 1e3,
            f"cold={g['fused_cold_ms']:.1f}ms "
            f"warm={g['fused_warm_ms']:.1f}ms",
        )
    )
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--backend",
        choices=("numpy", "jax", "pallas"),
        default=None,
        help="IR timing backend for the 64-cell sweep "
        "(default: REPRO_IR_BACKEND env, else numpy)",
    )
    cli = parser.parse_args()
    from repro.obs import get_logger

    log = get_logger("ir_sweep")
    for name, us, note in run(backend=cli.backend) + backend_rows():
        log.data(f"{name},{us:.1f},{note}")
