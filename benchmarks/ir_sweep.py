"""Batched scenario-sweep benchmark: array IR vs per-instance object path.

Builds a 64-instance sweep (8 message sizes x 8 reconfiguration delays) of
strawman-ICR decisions for Rabenseifner AllReduce on 8 nodes x 4 planes,
then evaluates it two ways:

* per instance through the *historical* object pipeline
  (`repro.core.simulator.execute` building ``PlaneActivity`` objects,
  validated with the interpreted ``validate_object`` oracle -- NOT the
  IR-routed ``Schedule.validate``, so the baseline carries none of the
  refactor's own conversion overhead), and
* in ONE `repro.core.ir.batch_evaluate` pass over the padded array set.

Reports wall-clock per instance for both plus the speedup; per-instance
CCTs must agree within 1e-9 (asserted here, not just in tests).  This is
the acceptance gate for the IR refactor: the batched pass must be >= 5x
faster than the object path.
"""

import time

import numpy as np

from repro.core import (
    BatchInstance,
    OpticalFabric,
    batch_evaluate,
    rabenseifner_allreduce,
    strawman_instance,
)
from repro.core.schedule import validate_object
from repro.core.simulator import execute


def _object_path_cct(inst: BatchInstance) -> float:
    """The pre-IR per-instance pipeline: build objects, validate, read CCT."""
    schedule = execute(
        inst.fabric, inst.pattern, inst.decisions, validate=False
    )
    validate_object(schedule)
    return schedule.cct

_N_NODES = 8
_N_PLANES = 4
_SIZES = tuple(2**i * 1e6 for i in range(8))  # 1 .. 128 MB
_RECFGS = tuple(25e-6 * 2**i for i in range(8))  # 25 us .. 3.2 ms


def _instances() -> list[BatchInstance]:
    return [
        strawman_instance(
            OpticalFabric(_N_NODES, _N_PLANES, t_recfg=t_recfg),
            rabenseifner_allreduce(_N_NODES, size),
            prestage=True,
        )
        for size in _SIZES
        for t_recfg in _RECFGS
    ]


def run(quick: bool = False) -> list[tuple[str, float, str]]:
    del quick  # the 64-cell sweep IS the CI smoke test
    instances = _instances()
    n = len(instances)
    # Best-of-3 on both sides: one-shot timings are too noisy for a CI
    # gate (first-call numpy warm-up, scheduler jitter).
    t_object = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        object_cct = np.array([_object_path_cct(i) for i in instances])
        t_object = min(t_object, time.perf_counter() - t0)
    t_batch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        result = batch_evaluate(instances)
        t_batch = min(t_batch, time.perf_counter() - t0)
    err = float(np.max(np.abs(result.cct - object_cct)))
    assert err <= 1e-9, f"batched CCT diverges from object path by {err}"
    speedup = t_object / t_batch
    assert speedup >= 5.0, (
        f"batched IR sweep only {speedup:.1f}x faster than the "
        "per-instance object path (acceptance gate is >= 5x)"
    )
    return [
        (
            "ir_sweep_object_path",
            t_object * 1e6 / n,
            f"{n} instances total={t_object * 1e3:.1f}ms",
        ),
        (
            "ir_sweep_batched",
            t_batch * 1e6 / n,
            f"speedup={speedup:.1f}x max_cct_err={err:.1e}",
        ),
    ]


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
