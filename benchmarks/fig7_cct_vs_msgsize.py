"""Paper Fig. 7: CCT vs message size per collective algorithm.

Setup (paper Section 4.1): 32 nodes on 4 OCS planes, 200 Gbps links,
200 us reconfiguration; pairwise all-to-all runs on 5 nodes ("due to
one-shot scalability constraints", i.e. 4 distinct configs fit 4 planes).
One-shot for Rabenseifner/Bruck at 32 nodes needs 5 distinct configs, so
it is granted minimal feasible provisioning (5 planes) -- the paper's
"excessive resource overprovisioning" arm -- while SWOT and Strawman-ICR
use the 4-plane fabric.

Checks recorded in EXPERIMENTS.md:
* SWOT vs one-shot reductions within/beyond the paper's ranges at large
  sizes (30.5-71.0% / 25.0-71.3% / 38.8-74.1%);
* one-shot is competitive below ~6.4 MB (reconfiguration-dominated);
* the SWOT-vs-strawman gap narrows beyond ~51.2 MB.
"""

from repro.core import (
    InfeasibleError,
    OpticalFabric,
    get_pattern,
    ideal_cct,
    one_shot,
    plan_collective,
    prestage_for,
)

SIZES_MB = (0.8, 1.6, 3.2, 6.4, 12.8, 25.6, 51.2, 102.4, 204.8, 409.6)

CASES = (
    ("rabenseifner_allreduce", 32),
    ("pairwise_alltoall", 5),
    ("bruck_alltoall", 32),
)


def run(sizes_mb=SIZES_MB) -> list[tuple[str, float, str]]:
    rows = []
    for algorithm, n_nodes in CASES:
        for size_mb in sizes_mb:
            pattern = get_pattern(algorithm, n_nodes, size_mb * 1e6)
            fabric = prestage_for(OpticalFabric(n_nodes, 4), pattern)
            one_shot_planes = max(4, pattern.n_distinct_configs)
            plan = plan_collective(
                fabric,
                pattern,
                one_shot_planes=one_shot_planes,
                milp_time_limit=10.0,
            )
            oneshot = (
                f"{plan.one_shot_cct * 1e6:.1f}"
                if plan.one_shot_cct is not None
                else "inf"
            )
            rows.append(
                (
                    f"fig7_{algorithm}_n{n_nodes}_{size_mb}MB",
                    plan.cct * 1e6,
                    f"strawman={plan.strawman_cct * 1e6:.1f}us "
                    f"oneshot={oneshot}us({one_shot_planes}pl) "
                    f"ideal={plan.ideal_cct * 1e6:.1f}us "
                    f"method={plan.method}",
                )
            )
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
