"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle vs model path.

CPU wall times validate FUNCTIONAL parity only -- the TPU is the target
for the Pallas path.  The derived column reports achieved GFLOP/s of the
pure-XLA blocked attention on this host as a sanity signal, plus the
analytic VMEM working set of each kernel's tiling (must be < ~16 MB).
"""

import time

import jax
import jax.numpy as jnp

from repro.analysis.constants import VMEM_BYTES


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(
        fn(*args), tuple
    ) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)

    # Blocked attention (model XLA path).
    from repro.models.attention import blocked_attention

    b, s, h, d = 2, 1024, 8, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    fn = jax.jit(
        lambda q, k, v: blocked_attention(q, k, v, q_block=256, kv_block=256)
    )
    us = _time(fn, q, k, v)
    flops = 4 * b * h * s * s * d / 2  # causal
    rows.append(
        (
            "kernel_blocked_attention_xla",
            us,
            f"{flops / us / 1e3:.1f}GFLOP/s host",
        )
    )

    # Pallas flash attention, interpret mode (functional).
    from repro.kernels import ops

    qs = q[:, :256]
    ks, vs = k[:, :256], v[:, :256]
    fn = jax.jit(
        lambda q, k, v: ops.flash_attention(
            q, k, v, q_block=128, kv_block=128, interpret=True
        )
    )
    us = _time(fn, qs, ks, vs)
    vmem = (128 * d * 2) * 3 + 128 * d * 4 + 128 * 8
    rows.append(
        (
            "kernel_flash_attention_pallas_interpret",
            us,
            f"vmem_tile={vmem / 1e3:.0f}KB<{VMEM_BYTES / 1e6:.0f}MB",
        )
    )

    # SSD scan kernel.
    x = jax.random.normal(key, (2, 512, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (2, 512, 4)))
    a_log = jax.random.normal(key, (4,)) * 0.5
    bb = jax.random.normal(key, (2, 512, 64))
    cc = jax.random.normal(key, (2, 512, 64))
    fn = jax.jit(
        lambda *a: ops.ssd_scan(*a, chunk=128, interpret=True)
    )
    us = _time(fn, x, dt, a_log, bb, cc)
    vmem = 128 * 128 * 4 + 2 * 128 * 64 * 4 + 64 * 64 * 4
    rows.append(
        (
            "kernel_ssd_scan_pallas_interpret",
            us,
            f"vmem_tile={vmem / 1e3:.0f}KB",
        )
    )

    # Fused reduce (the collective local-combine).
    a = jax.random.normal(key, (1 << 20,), jnp.bfloat16)
    b2 = jax.random.normal(key, (1 << 20,), jnp.bfloat16)
    fn = jax.jit(
        lambda a, b: ops.fused_reduce(a, b, interpret=True)
    )
    us = _time(fn, a, b2)
    rows.append(
        (
            "kernel_fused_reduce_pallas_interpret",
            us,
            f"{3 * a.size * 2 / us / 1e3:.2f}GB/s host",
        )
    )

    # RMSNorm.
    x = jax.random.normal(key, (2048, 1024), jnp.bfloat16)
    w = jax.random.normal(key, (1024,))
    fn = jax.jit(lambda x, w: ops.rmsnorm(x, w, interpret=True))
    us = _time(fn, x, w)
    rows.append(("kernel_rmsnorm_pallas_interpret", us, "functional"))

    # Schedule-IR timing scan (the batched sweep recurrence).  Interpret
    # mode wall time is the interpreter's; the parity vs the numpy
    # backend is the signal (also gated in tests/test_ir_backends.py).
    import numpy as np

    from repro.core import OpticalFabric, get_pattern, strawman_instance
    from repro.core.ir import get_backend
    from repro.core.ir.engine import pack_instances
    from repro.kernels.timing_scan import timing_scan

    instances = [
        strawman_instance(
            OpticalFabric(8, 4, t_recfg=25e-6 * (1 + k)),
            get_pattern("rabenseifner_allreduce", 8, 1e6 * (1 + k)),
            prestage=True,
        )
        for k in range(32)
    ]
    packed = pack_instances(instances, None)
    from jax.experimental import enable_x64

    with enable_x64():
        fn = lambda: timing_scan(packed, interpret=True)
        jax.block_until_ready(fn()[0])
        t0 = time.perf_counter()
        cct = fn()[0]
        jax.block_until_ready(cct)
        us = (time.perf_counter() - t0) * 1e6
    err = float(
        np.max(np.abs(np.asarray(cct) - get_backend("numpy")
                      .derive_timing(packed).cct))
    )
    rows.append(
        (
            "kernel_timing_scan_pallas_interpret",
            us,
            f"{len(instances)} cells max_cct_err={err:.1e}",
        )
    )
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
