"""Multi-tenant fabric arbitration sweep + fleet-scale runtime gate.

Replays Poisson traces of model-config-derived collectives through the
``repro.runtime`` arbiter and reports, per cell:

* mean realized CCT and mean/p95 queueing delay per job,
* mean plane utilization over the replay makespan,
* mean slowdown vs the whole-fabric solo CCT of the same collective.

The degenerate 1-tenant cell doubles as a regression anchor: with one job
in flight at a time the arbiter must realize exactly the serial
scheduler's CCT (asserted in tests/test_runtime.py; here it shows up as
slowdown 1.00x for hot circuits).

Two runtime-scale sections follow the sweep (ROADMAP item 2):

* **Parity reference** -- the canonical 19-job quick-cell trace replayed
  with the arbiter's memoized/batched path OFF (the legacy serial path)
  and ON; the two reports are asserted bit-identical in-run, and the
  legacy events/sec becomes the denominator for the speedup gate.
* **Scale** -- a 10,000-job heavy-tailed/diurnal trace
  (``heavy_tailed_trace``) replayed cold (empty plan cache; wall time
  includes all one-time planning) and warm (second replay against the
  now-populated shared cache -- the steady state a million-event serving
  run operates in).  ``mt_scale_speedup`` (warm events/sec over legacy
  events/sec, both measured in this process so the ratio is
  machine-independent) is asserted >= 50x in-run and hard-gated in
  ``check_regression.py``; the cold ratio and cache hit rate ride along.
"""

from __future__ import annotations

import re
import time

from repro.configs.registry import get_config
from repro.core import (
    BatchInstance,
    OpticalFabric,
    batch_evaluate,
    get_pattern,
    strawman_instance,
)
from repro.runtime import (
    PlanCache,
    arch_request_mix,
    heavy_tailed_trace,
    poisson_trace,
    replay,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import SLOMonitor, SLOTarget
from repro.trace import overlap_comparison, replay_trace, static_trace

# Fleet-scale trace defaults (the gated 10k-job heavy-tailed replay).
_SCALE_JOBS = 10_000
_SCALE_RATE = 60.0  # arrivals/s: bursty overlap without miss blowup
_SCALE_SIGMA = 0.8  # lognormal size spread (pow2-snapped, see workload)
_SCALE_SEED = 11
# Per-tenant SLO deadline (arrival -> finish) for the scale replay's
# miss-rate rows: picked so every tenant lands strictly inside (0, 1)
# at the default scale knobs (p90..p99 responses straddle it -- the MoE
# tenant misses ~18%, the small dense tenants <1%), keeping the gated
# rates sensitive in both directions.
_SLO_DEADLINE_S = 2e-3
# Hard floor asserted in-run and gated in check_regression.py: warm
# steady-state events/sec must beat the legacy per-job planning path by
# this factor on the same machine in the same process.
_SCALE_SPEEDUP_FLOOR = 50.0

# Tenant pool: one training job per architecture family (dense, MoE).
_TENANT_ARCHS = ("qwen3_4b", "gemma_2b", "qwen2_moe_a2_7b", "qwen2_1_5b")

_N_NODES = 8
# Modest message scale keeps every cell sub-second of sim *and* wall time.
_TOKENS_PER_STEP = 16_384
_SIZE_SCALE = 1 / 256  # shrink analytic DP-sync sizes to benchmark scale
# Model-trace replays scale further: per-job transmission must be
# comparable to t_recfg (200us) for reconfiguration overlap to matter --
# the paper's operating regime.  At 1/256 the count-folded per-layer
# payloads are ~100MB+ and transmission swamps reconfiguration.
_TRACE_SIZE_SCALE = 1 / 4096


def _tenant_mixes(n_tenants: int):
    tenants = []
    for name in _TENANT_ARCHS[:n_tenants]:
        mix = arch_request_mix(
            get_config(name),
            n_nodes=_N_NODES,
            tokens_per_step=_TOKENS_PER_STEP,
        )
        mix = [
            type(r)(r.algorithm, r.n_nodes, r.size * _SIZE_SCALE, r.tag)
            for r in mix
        ]
        tenants.append((name, mix))
    return tenants


def _record_key(report):
    """Everything the bit-identical parity contract covers, per job."""
    return [
        (
            r.job_id,
            r.tag,
            r.start,
            r.finish,
            r.cct,
            r.queueing_delay,
            r.replans,
            r.planes_min,
            r.planes_max,
            r.rejected,
        )
        for r in report.records
    ]


def _assert_parity(legacy, optimized) -> None:
    """Bit-identical ``ReplayReport`` with the memoized path on vs off."""
    assert _record_key(legacy) == _record_key(optimized), (
        "memoized replay diverged from the legacy path (records)"
    )
    assert legacy.makespan == optimized.makespan
    assert legacy.stats == optimized.stats, (
        "memoized replay diverged from the legacy path (stats)"
    )
    assert legacy.events_fired == optimized.events_fired


def _close(a: float, b: float, rel: float = 1e-9) -> bool:
    return abs(a - b) <= rel * max(abs(a), abs(b), 1e-30)


def _assert_stream_parity(acc, streamed) -> None:
    """The memory-flat streamed replay must serve the accumulated
    replay's statistics from its registry: counts and means exact (float
    summation order aside), percentiles within the queue-wait
    histogram's documented error bound."""
    assert streamed.records == [], "streaming replay accumulated records"
    assert streamed.n_jobs == acc.n_jobs
    assert streamed.n_completed == acc.n_completed
    assert _close(streamed.mean_cct, acc.mean_cct)
    assert _close(streamed.mean_queueing_delay, acc.mean_queueing_delay)
    err = (
        streamed.metrics.get("fabric_queue_wait_seconds")
        .aggregate()
        .quantile_error
    )

    def in_bound(est: float, true: float) -> bool:
        return true * (1 - 1e-9) <= est <= true * (1 + err) * (1 + 1e-9)

    assert in_bound(streamed.p95_queueing_delay, acc.p95_queueing_delay)
    assert in_bound(streamed.p99_queueing_delay, acc.p99_queueing_delay)
    acc_tenants = acc.per_tenant()
    str_tenants = streamed.per_tenant()
    assert set(acc_tenants) == set(str_tenants)
    for tenant, a in acc_tenants.items():
        s = str_tenants[tenant]
        assert (s.n_jobs, s.n_completed, s.n_rejected) == (
            a.n_jobs, a.n_completed, a.n_rejected,
        )
        assert _close(s.total_bytes, a.total_bytes)
        assert _close(s.mean_cct, a.mean_cct)
        assert _close(s.mean_queueing_delay, a.mean_queueing_delay)
        assert in_bound(s.p95_queueing_delay, a.p95_queueing_delay)
        assert _close(s.overlap_efficiency, a.overlap_efficiency)


def run(
    quick: bool = False,
    jobs: int | None = None,
    arrival: float | None = None,
    tracer=None,
    metrics: MetricsRegistry | None = None,
) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    t_wall = time.perf_counter()
    # Per-phase wall-clock accounting (the ``_us``-suffixed rows below):
    # where a multi-tenant bench run actually spends its time, and the
    # replay events/sec throughput that seeds ROADMAP item 2's gate.
    t_ref_phase = t_trace_phase = t_replay_phase = 0.0
    events_total = 0
    if quick:
        cells = [(2, 4, 200e-6)]
        rate, horizon = 30.0, 0.25
    else:
        cells = [
            (n_tenants, n_planes, t_recfg)
            for n_tenants in (1, 2, 4)
            for n_planes in (2, 4, 8)
            for t_recfg in (50e-6, 200e-6)
        ]
        rate, horizon = 30.0, 0.5
    # Whole-sweep lockstep-ICR reference: every (cell, collective
    # signature) pair becomes one row of a single batched IR evaluation
    # (timing backend follows REPRO_IR_BACKEND, like every IR sweep).
    t0 = time.perf_counter()
    ref_keys: list[tuple[int, tuple]] = []
    ref_instances: list[BatchInstance] = []
    for idx, (n_tenants, n_planes, t_recfg) in enumerate(cells):
        base = OpticalFabric(_N_NODES, n_planes, t_recfg=t_recfg)
        seen = set()
        for _name, mix in _tenant_mixes(n_tenants):
            for req in mix:
                if req.signature in seen:
                    continue
                seen.add(req.signature)
                pattern = get_pattern(req.algorithm, req.n_nodes, req.size)
                ref_keys.append((idx, req.signature))
                ref_instances.append(
                    strawman_instance(base, pattern, prestage=True)
                )
    ref_ccts = batch_evaluate(ref_instances).cct
    straw_by_cell: dict[int, list[float]] = {}
    for (idx, _sig), cct in zip(ref_keys, ref_ccts):
        straw_by_cell.setdefault(idx, []).append(float(cct))
    t_ref_phase = time.perf_counter() - t0

    for idx, (n_tenants, n_planes, t_recfg) in enumerate(cells):
        fabric = OpticalFabric(_N_NODES, n_planes, t_recfg=t_recfg)
        t0 = time.perf_counter()
        trace = poisson_trace(
            _tenant_mixes(n_tenants),
            rate=rate,
            horizon=horizon,
            seed=7,
        )
        t_trace_phase += time.perf_counter() - t0
        t0 = time.perf_counter()
        report = replay(trace, fabric, method="greedy")
        t_replay_phase += time.perf_counter() - t0
        events_total += report.events_fired
        cell = (
            f"mt_t{n_tenants}_p{n_planes}_r{t_recfg * 1e6:.0f}us"
        )
        straw_ref = straw_by_cell[idx]
        mean_straw = sum(straw_ref) / len(straw_ref)
        rows.append(
            (
                f"{cell}_cct",
                report.mean_cct * 1e6,
                f"{len(report.completed)}jobs "
                f"util={report.utilization:.2f} "
                f"slowdown={report.mean_slowdown():.2f}x "
                f"straw_ref={mean_straw * 1e6:.1f}us",
            )
        )
        rows.append(
            (
                f"{cell}_queue",
                report.mean_queueing_delay * 1e6,
                f"p95={report.p95_queueing_delay * 1e6:.1f}us "
                f"replans={report.stats.replans}",
            )
        )
    rows.append(
        (
            "mt_phase_solo_ref_us",
            t_ref_phase * 1e6,
            f"{len(ref_instances)} solo-reference instances (wall)",
        )
    )
    rows.append(
        (
            "mt_phase_tracegen_us",
            t_trace_phase * 1e6,
            f"{len(cells)} cells (wall)",
        )
    )
    rows.append(
        (
            "mt_phase_replay_us",
            t_replay_phase * 1e6,
            f"{events_total} sim events (wall)",
        )
    )
    rows.append(
        (
            "mt_events_per_sec",
            events_total / t_replay_phase if t_replay_phase else 0.0,
            f"{events_total} events in {t_replay_phase * 1e3:.1f}ms "
            "of replay (wall)",
        )
    )

    # -- parity reference: canonical 19-job trace, legacy path vs hot path
    parity_fabric = OpticalFabric(_N_NODES, 4, t_recfg=200e-6)
    parity_trace = poisson_trace(
        _tenant_mixes(2), rate=30.0, horizon=0.25, seed=7
    )
    t0 = time.perf_counter()
    legacy_report = replay(
        parity_trace, parity_fabric, optimize=False, solo_refs=False
    )
    t_legacy = time.perf_counter() - t0
    optimized_report = replay(
        parity_trace, parity_fabric, optimize=True, solo_refs=False
    )
    _assert_parity(legacy_report, optimized_report)
    legacy_eps = legacy_report.events_fired / t_legacy
    rows.append(
        (
            "mt_phase_parity_legacy_us",
            t_legacy * 1e6,
            f"{legacy_report.events_fired} events at "
            f"{legacy_eps:.1f} ev/s on the legacy (optimize=False) path; "
            "bit-identical to the memoized path (asserted)",
        )
    )

    # -- fleet scale: 10k-job heavy-tailed trace, cold then warm cache
    n_jobs = jobs if jobs is not None else _SCALE_JOBS
    rate_scale = arrival if arrival is not None else _SCALE_RATE
    t0 = time.perf_counter()
    scale_trace = heavy_tailed_trace(
        _tenant_mixes(4),
        n_jobs=n_jobs,
        rate=rate_scale,
        seed=_SCALE_SEED,
        sigma=_SCALE_SIGMA,
    )
    t_scale_tracegen = time.perf_counter() - t0
    scale_fabric = OpticalFabric(_N_NODES, 4, t_recfg=200e-6)
    cache = PlanCache()
    t0 = time.perf_counter()
    cold = replay(
        scale_trace,
        scale_fabric,
        solo_refs=False,
        plan_cache=cache,
        tracer=tracer,
    )
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = replay(
        scale_trace, scale_fabric, solo_refs=False, plan_cache=cache
    )
    t_warm = time.perf_counter() - t0
    cold_eps = cold.events_fired / t_cold
    warm_eps = warm.events_fired / t_warm
    speedup = warm_eps / legacy_eps
    speedup_cold = cold_eps / legacy_eps
    assert speedup >= _SCALE_SPEEDUP_FLOOR, (
        f"scale replay only {speedup:.1f}x the legacy path "
        f"(floor {_SCALE_SPEEDUP_FLOOR}x)"
    )
    rows.append(
        (
            "mt_scale_events_per_sec",
            cold_eps,
            f"{cold.events_fired} events, {n_jobs} heavy-tailed jobs, "
            f"cold cache ({cold.cache.misses} plan misses, "
            f"{t_cold * 1e3:.0f}ms wall incl. "
            f"{cold.cache.plan_wall_s * 1e3:.0f}ms planning)",
        )
    )
    rows.append(
        (
            "mt_scale_warm_events_per_sec",
            warm_eps,
            f"{warm.events_fired} events, warm shared cache "
            f"({t_warm * 1e3:.0f}ms wall) -- steady-state throughput",
        )
    )
    rows.append(
        (
            "mt_scale_speedup",
            speedup,
            f"warm {warm_eps:.0f} ev/s vs legacy {legacy_eps:.1f} ev/s "
            f"(same run; cold ratio {speedup_cold:.1f}x)",
        )
    )
    rows.append(
        (
            "mt_cache_hit_rate",
            cold.cache.hit_rate,
            f"{cold.cache.hits}/{cold.cache.hits + cold.cache.misses} "
            f"plan lookups hit on the cold pass; release memo "
            f"{cold.cache.release_hits}h/{cold.cache.release_misses}m",
        )
    )
    rows.append(
        (
            "mt_phase_scale_plan_us",
            cold.cache.plan_wall_s * 1e6,
            f"{cold.cache.misses} cache-miss plans (wall)",
        )
    )
    rows.append(
        (
            "mt_phase_scale_replay_us",
            max(0.0, t_cold - cold.cache.plan_wall_s) * 1e6,
            "cold-pass event loop outside planning (wall)",
        )
    )
    rows.append(
        (
            "mt_phase_scale_tracegen_us",
            t_scale_tracegen * 1e6,
            f"{n_jobs}-job heavy-tailed trace generation (wall)",
        )
    )

    # -- streaming replay: the same scale trace, memory-flat -------------
    # No JobRecord list accumulates; every statistic (and the SLO rows
    # below) comes from the live metrics registry.  Asserted in-run to
    # match the accumulated warm replay within the histogram's
    # documented quantile error bound.
    reg = metrics if metrics is not None else MetricsRegistry()
    slo = SLOMonitor(
        default=SLOTarget(deadline=_SLO_DEADLINE_S), registry=reg
    )
    t0 = time.perf_counter()
    streamed = replay(
        scale_trace,
        scale_fabric,
        plan_cache=cache,
        stream=True,
        metrics=reg,
        slo=slo,
    )
    t_stream = time.perf_counter() - t0
    _assert_stream_parity(warm, streamed)
    rows.append(
        (
            "mt_stream_events_per_sec",
            streamed.events_fired / t_stream,
            f"{streamed.events_fired} events streamed (no record list, "
            f"{t_stream * 1e3:.0f}ms wall); stats match the accumulated "
            "replay within histogram bounds (asserted)",
        )
    )
    rows.append(
        (
            "mt_p99_wait_us",
            warm.p99_queueing_delay * 1e6,
            f"p99 admission wait over {warm.n_completed} scale jobs "
            f"(streamed estimate {streamed.p99_queueing_delay * 1e6:.1f}"
            "us from the log-bucketed histogram)",
        )
    )
    for tenant, ts in sorted(warm.per_tenant().items()):
        rows.append(
            (
                f"mt_scale_{tenant}_overlap_eff",
                ts.overlap_efficiency,
                f"hidden/(hidden+exposed) reconfiguration over "
                f"{ts.n_completed} completed jobs",
            )
        )
        rows.append(
            (
                f"mt_scale_{tenant}_deadline_miss_rate",
                slo.miss_rate(tenant),
                f"jobs finishing later than "
                f"{_SLO_DEADLINE_S * 1e3:.0f}ms after arrival "
                f"(windowed p99 {slo.window_quantiles(tenant)[2] * 1e3:.2f}ms)",
            )
        )

    # -- model-trace replay: closed-loop traces from the real model stack
    # Static per-step collective traces (repro.trace) replayed through
    # the arbiter with the SWOT planner vs the strawman-ICR baseline:
    # deterministic per-model end-to-end step times with and without
    # intra-collective reconfiguration overlap, plus a co-located
    # scenario (MoE training beside dense serving on ONE shared fabric).
    t0 = time.perf_counter()
    trace_fabric = OpticalFabric(_N_NODES, 4, t_recfg=200e-6)
    model_steps = 1 if quick else 2
    for arch in ("gemma_2b", "qwen2_moe_a2_7b"):
        mt = static_trace(
            arch, kind="train", dp=2, tp=4, n_steps=model_steps
        )
        comp = overlap_comparison(
            mt, trace_fabric, size_scale=_TRACE_SIZE_SCALE
        )[mt.model]
        rows.append(
            (
                f"model_trace_{arch}_step_cct",
                comp["step_time"] * 1e6,
                f"{mt.n_events} events/step x{mt.n_steps} steps, "
                "SWOT overlap on",
            )
        )
        rows.append(
            (
                f"model_trace_{arch}_strawman_cct",
                comp["strawman_step_time"] * 1e6,
                "same trace, strawman-ICR (overlap off)",
            )
        )
        rows.append(
            (
                f"model_trace_{arch}_overlap_gain",
                comp["overlap_gain"],
                "fractional step-time reduction from overlap",
            )
        )
    colo_traces = [
        static_trace(
            "qwen2_moe_a2_7b", kind="train", dp=2, tp=4,
            n_steps=model_steps,
        ),
        static_trace(
            "gemma_2b", kind="prefill", dp=2, tp=4, n_steps=model_steps
        ),
    ]
    colo_report, colo_times = replay_trace(
        colo_traces, trace_fabric, size_scale=_TRACE_SIZE_SCALE
    )
    for arch, st in sorted(colo_times.items()):
        tstats = colo_report.per_tenant()[arch]
        rows.append(
            (
                f"model_trace_colo_{arch}_step_cct",
                st.step_time * 1e6,
                f"co-located train+serve on one fabric; "
                f"{st.n_completed}/{st.n_jobs} jobs, mean queue "
                f"{tstats.mean_queueing_delay * 1e6:.1f}us",
            )
        )
    # Per-collective-site exposed-reconfiguration fraction over the
    # co-located replay (the attribution rollup, straight from the
    # JobRecord components): exposed/(exposed+hidden), lower is better.
    site_recfg: dict[str, list[float]] = {}
    for r in colo_report.completed:
        acc = site_recfg.setdefault(r.site, [0.0, 0.0, 0])
        acc[0] += r.t_recfg_exposed
        acc[1] += r.t_recfg_hidden
        acc[2] += 1
    for site, (exposed, hidden, n_done) in sorted(site_recfg.items()):
        if exposed + hidden <= 0.0:
            continue  # site carried no reconfigurations
        slug = re.sub(r"[^0-9A-Za-z]+", "_", site).strip("_")
        rows.append(
            (
                f"model_trace_site_{slug}_exposed_frac",
                exposed / (exposed + hidden),
                f"exposed share of {(exposed + hidden) * 1e6:.1f}us "
                f"plane-mean reconfiguration over {n_done} jobs at "
                f"site {site}",
            )
        )
    rows.append(
        (
            "mt_phase_model_trace_us",
            (time.perf_counter() - t0) * 1e6,
            "model-trace extraction + overlap on/off replays (wall)",
        )
    )

    rows.append(
        (
            "multi_tenant_wall_time",
            (time.perf_counter() - t_wall) * 1e6,
            "bench runtime",
        )
    )
    return rows


if __name__ == "__main__":
    import argparse
    import contextlib
    import json

    from repro.obs import ChromeTracer, get_logger

    parser = argparse.ArgumentParser(
        description="multi-tenant arbitration sweep + runtime scale gate"
    )
    parser.add_argument(
        "--quick", action="store_true", help="single sweep cell"
    )
    parser.add_argument(
        "--jobs",
        type=int,
        default=None,
        help=f"scale-trace job count (default {_SCALE_JOBS})",
    )
    parser.add_argument(
        "--arrival",
        type=float,
        default=None,
        help=f"scale-trace mean arrival rate/s (default {_SCALE_RATE})",
    )
    parser.add_argument(
        "--trace",
        metavar="OUT.json",
        default=None,
        help="record the cold scale replay with ChromeTracer to this file",
    )
    parser.add_argument(
        "--metrics-out",
        metavar="BASE",
        default=None,
        help="export the streamed scale replay's live metrics to "
        "BASE.json (full fidelity) and BASE.prom (Prometheus text)",
    )
    args = parser.parse_args()

    log = get_logger("multi_tenant_bench")
    metrics = MetricsRegistry() if args.metrics_out else None
    # Context-managed tracer: the Chrome trace flushes even if a replay
    # assertion trips mid-run.
    with contextlib.ExitStack() as stack:
        tracer = None
        if args.trace:
            tracer = stack.enter_context(ChromeTracer(path=args.trace))
        for name, us, note in run(
            quick=args.quick,
            jobs=args.jobs,
            arrival=args.arrival,
            tracer=tracer,
            metrics=metrics,
        ):
            log.data(f"{name},{us:.1f},{note}")
    if tracer is not None:
        log.info(f"wrote {args.trace}")
    if args.metrics_out:
        with open(args.metrics_out + ".json", "w") as fh:
            json.dump(metrics.to_json(), fh)
        with open(args.metrics_out + ".prom", "w") as fh:
            fh.write(metrics.to_prometheus_text())
        log.info(
            f"wrote {args.metrics_out}.json and {args.metrics_out}.prom "
            f"({len(metrics.families())} metric families)"
        )
