"""Multi-tenant fabric arbitration sweep: tenants x planes x t_recfg.

Replays Poisson traces of model-config-derived collectives through the
``repro.runtime`` arbiter and reports, per cell:

* mean realized CCT and mean/p95 queueing delay per job,
* mean plane utilization over the replay makespan,
* mean slowdown vs the whole-fabric solo CCT of the same collective.

The degenerate 1-tenant cell doubles as a regression anchor: with one job
in flight at a time the arbiter must realize exactly the serial
scheduler's CCT (asserted in tests/test_runtime.py; here it shows up as
slowdown 1.00x for hot circuits).
"""

from __future__ import annotations

import time

from repro.configs.registry import get_config
from repro.core import (
    BatchInstance,
    OpticalFabric,
    batch_evaluate,
    get_pattern,
    strawman_instance,
)
from repro.runtime import arch_request_mix, poisson_trace, replay

# Tenant pool: one training job per architecture family (dense, MoE).
_TENANT_ARCHS = ("qwen3_4b", "gemma_2b", "qwen2_moe_a2_7b", "qwen2_1_5b")

_N_NODES = 8
# Modest message scale keeps every cell sub-second of sim *and* wall time.
_TOKENS_PER_STEP = 16_384
_SIZE_SCALE = 1 / 256  # shrink analytic DP-sync sizes to benchmark scale


def _tenant_mixes(n_tenants: int):
    tenants = []
    for name in _TENANT_ARCHS[:n_tenants]:
        mix = arch_request_mix(
            get_config(name),
            n_nodes=_N_NODES,
            tokens_per_step=_TOKENS_PER_STEP,
        )
        mix = [
            type(r)(r.algorithm, r.n_nodes, r.size * _SIZE_SCALE, r.tag)
            for r in mix
        ]
        tenants.append((name, mix))
    return tenants


def run(quick: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    t_wall = time.perf_counter()
    # Per-phase wall-clock accounting (the ``_us``-suffixed rows below):
    # where a multi-tenant bench run actually spends its time, and the
    # replay events/sec throughput that seeds ROADMAP item 2's gate.
    t_ref_phase = t_trace_phase = t_replay_phase = 0.0
    events_total = 0
    if quick:
        cells = [(2, 4, 200e-6)]
        rate, horizon = 30.0, 0.25
    else:
        cells = [
            (n_tenants, n_planes, t_recfg)
            for n_tenants in (1, 2, 4)
            for n_planes in (2, 4, 8)
            for t_recfg in (50e-6, 200e-6)
        ]
        rate, horizon = 30.0, 0.5
    # Whole-sweep lockstep-ICR reference: every (cell, collective
    # signature) pair becomes one row of a single batched IR evaluation
    # (timing backend follows REPRO_IR_BACKEND, like every IR sweep).
    t0 = time.perf_counter()
    ref_keys: list[tuple[int, tuple]] = []
    ref_instances: list[BatchInstance] = []
    for idx, (n_tenants, n_planes, t_recfg) in enumerate(cells):
        base = OpticalFabric(_N_NODES, n_planes, t_recfg=t_recfg)
        seen = set()
        for _name, mix in _tenant_mixes(n_tenants):
            for req in mix:
                if req.signature in seen:
                    continue
                seen.add(req.signature)
                pattern = get_pattern(req.algorithm, req.n_nodes, req.size)
                ref_keys.append((idx, req.signature))
                ref_instances.append(
                    strawman_instance(base, pattern, prestage=True)
                )
    ref_ccts = batch_evaluate(ref_instances).cct
    straw_by_cell: dict[int, list[float]] = {}
    for (idx, _sig), cct in zip(ref_keys, ref_ccts):
        straw_by_cell.setdefault(idx, []).append(float(cct))
    t_ref_phase = time.perf_counter() - t0

    for idx, (n_tenants, n_planes, t_recfg) in enumerate(cells):
        fabric = OpticalFabric(_N_NODES, n_planes, t_recfg=t_recfg)
        t0 = time.perf_counter()
        trace = poisson_trace(
            _tenant_mixes(n_tenants),
            rate=rate,
            horizon=horizon,
            seed=7,
        )
        t_trace_phase += time.perf_counter() - t0
        t0 = time.perf_counter()
        report = replay(trace, fabric, method="greedy")
        t_replay_phase += time.perf_counter() - t0
        events_total += report.events_fired
        cell = (
            f"mt_t{n_tenants}_p{n_planes}_r{t_recfg * 1e6:.0f}us"
        )
        straw_ref = straw_by_cell[idx]
        mean_straw = sum(straw_ref) / len(straw_ref)
        rows.append(
            (
                f"{cell}_cct",
                report.mean_cct * 1e6,
                f"{len(report.completed)}jobs "
                f"util={report.utilization:.2f} "
                f"slowdown={report.mean_slowdown():.2f}x "
                f"straw_ref={mean_straw * 1e6:.1f}us",
            )
        )
        rows.append(
            (
                f"{cell}_queue",
                report.mean_queueing_delay * 1e6,
                f"p95={report.p95_queueing_delay * 1e6:.1f}us "
                f"replans={report.stats.replans}",
            )
        )
    rows.append(
        (
            "mt_phase_solo_ref_us",
            t_ref_phase * 1e6,
            f"{len(ref_instances)} solo-reference instances (wall)",
        )
    )
    rows.append(
        (
            "mt_phase_tracegen_us",
            t_trace_phase * 1e6,
            f"{len(cells)} cells (wall)",
        )
    )
    rows.append(
        (
            "mt_phase_replay_us",
            t_replay_phase * 1e6,
            f"{events_total} sim events (wall)",
        )
    )
    rows.append(
        (
            "mt_events_per_sec",
            events_total / t_replay_phase if t_replay_phase else 0.0,
            f"{events_total} events in {t_replay_phase * 1e3:.1f}ms "
            "of replay (wall)",
        )
    )
    rows.append(
        (
            "multi_tenant_wall_time",
            (time.perf_counter() - t_wall) * 1e6,
            "bench runtime",
        )
    )
    return rows


if __name__ == "__main__":
    from repro.obs import get_logger

    log = get_logger("multi_tenant_bench")
    for name, us, note in run():
        log.data(f"{name},{us:.1f},{note}")
