"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:

* fig5_motivation  -- paper Fig. 5 (exact published CCTs)
* fig7_cct_vs_msgsize -- paper Fig. 7(a-c)
* fig8_scalability -- paper Fig. 8(a-b)
* scheduler_bench  -- solve-time vs the paper's Gurobi claim
* kernel_bench     -- Pallas kernel microbenches (interpret mode)
* swot_ladder      -- optical scheduling modes on a real step's
                      collectives (EXPERIMENTS.md section 4.1)
* multi_tenant_bench -- concurrent collectives on a shared fabric
                      (tenants x planes x t_recfg sweep)
* ir_sweep         -- batched array-IR scenario sweep vs the
                      per-instance object path (>= 5x gate)

Usage: ``python benchmarks/run.py [module-substring] [--quick]``.
``--quick`` runs a single-cell smoke sweep per module that supports it
(CI uses this).

Every unfiltered run (no module substring) also writes
``BENCH_sweep.json`` at the repo root: the same per-point values (CCTs
in us for schedule points, wall-clock in us for scheduling/validation
points) plus per-module wall-clock seconds, so the perf trajectory is
machine-readable across PRs.  Module-filtered runs skip the write, and
full (non ``--quick``) sweeps write ``BENCH_sweep_full.json`` instead,
so neither ever clobbers the tracked file.  The committed flavor is the
``--quick`` output (the cell CI runs every PR) — regenerate it with
``PYTHONPATH=src:. python benchmarks/run.py --quick`` when benchmarks
change.

Unfiltered runs additionally write the IR timing-backend throughput
comparison (numpy vs jax vs pallas-interpret on the large ``ir_sweep``
grid, cold/compile and warm timed separately, including the >= 2x
jax-vs-numpy acceptance gate) plus the fused on-device planner gate
(``fused_grid``: the whole CHAIN greedy loop as one jitted ``lax.scan``,
>= 2x warm vs the per-step numpy loop with 0 decision mismatches):
``BENCH_backends.json`` for ``--quick`` (the tracked, CI-comparable
flavor) and ``BENCH_backends_full.json`` otherwise, so backend speedups
are tracked across PRs alongside the sweep numbers.
"""

import json
import pathlib
import sys
import time

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO_ROOT / "src"))

from repro.obs import get_logger  # noqa: E402

log = get_logger("benchmarks")


def main() -> None:
    from benchmarks import (
        fig5_motivation,
        fig7_cct_vs_msgsize,
        fig8_scalability,
        ir_sweep,
        kernel_bench,
        multi_tenant_bench,
        scheduler_bench,
        swot_ladder,
    )

    modules = [
        fig5_motivation,
        fig7_cct_vs_msgsize,
        fig8_scalability,
        scheduler_bench,
        kernel_bench,
        swot_ladder,
        multi_tenant_bench,
        ir_sweep,
    ]
    args = [a for a in sys.argv[1:]]
    quick = "--quick" in args
    args = [a for a in args if a != "--quick"]
    only = args[0] if args else None
    points: list[dict] = []
    module_wall: dict[str, float] = {}
    # CSV rows are the program's machine-readable contract -- they go
    # through the always-on data channel; REPRO_LOG only affects the
    # narrative channel.
    log.data("name,us_per_call,derived")
    for module in modules:
        if only and only not in module.__name__:
            continue
        t_wall = time.perf_counter()
        if quick:
            import inspect

            if "quick" in inspect.signature(module.run).parameters:
                rows = module.run(quick=True)
            elif only or module is fig5_motivation:
                rows = module.run()  # cheap (or explicitly requested)
            else:
                continue  # no quick mode: skipped in CI smoke runs
        else:
            rows = module.run()
        module_wall[module.__name__] = time.perf_counter() - t_wall
        for name, us, note in rows:
            log.data(f"{name},{us:.1f},{note}")
            points.append(
                {"name": name, "us_per_call": round(us, 3), "note": note}
            )
    if only:
        return  # partial run: don't clobber the tracked sweep file
    # Backend throughput comparison (and the jax >= 2x gate) on the
    # large grid; its own JSON so the trajectory file stays diffable.
    # Same no-clobber policy as the sweep file: the tracked name holds
    # the CI-comparable --quick flavor, full runs land in a sibling.
    backends_payload = ir_sweep.backend_throughput(quick=quick)
    for name, entry in backends_payload["backends"].items():
        note = (
            "unavailable"
            if "ms" not in entry
            else f"total={entry['ms']:.1f}ms "
            f"speedup={entry['speedup_vs_numpy']}x "
            f"compile={entry['compile_ms']:.1f}ms"
        )
        us = entry.get("us_per_instance", 0.0)
        log.data(f"ir_backend_{name},{us:.1f},{note}")
    fused = backends_payload["fused_grid"]
    log.data(
        f"fused_grid,{fused['us_per_cell']:.1f},"
        f"per_step={fused['per_step_ms']:.0f}ms "
        f"warm={fused['fused_warm_ms']:.0f}ms "
        f"cold={fused['fused_cold_ms']:.0f}ms "
        f"speedup={fused['speedup_vs_per_step']}x "
        f"mismatches={fused['decision_mismatches']}"
    )
    # Machine-independent runtime-scale ratio (warm memoized replay vs
    # the legacy per-event path, measured in the same run) -- hard-gated
    # by check_regression.py alongside the backend speedups.
    by_name = {p["name"]: p for p in points}
    if "mt_scale_speedup" in by_name:
        backends_payload["multi_tenant_scale"] = {
            "speedup_vs_serial_path": by_name["mt_scale_speedup"][
                "us_per_call"
            ],
            "cache_hit_rate": by_name.get("mt_cache_hit_rate", {}).get(
                "us_per_call"
            ),
            "note": by_name["mt_scale_speedup"]["note"],
        }
    backends_name = (
        "BENCH_backends.json" if quick else "BENCH_backends_full.json"
    )
    (_REPO_ROOT / backends_name).write_text(
        json.dumps(backends_payload, indent=1) + "\n"
    )
    payload = {
        "quick": quick,
        "module_wall_clock_s": {
            k: round(v, 4) for k, v in module_wall.items()
        },
        "points": points,
    }
    # The tracked file holds only the CI-comparable --quick flavor; full
    # local sweeps land in an untracked sibling.
    name = "BENCH_sweep.json" if quick else "BENCH_sweep_full.json"
    (_REPO_ROOT / name).write_text(json.dumps(payload, indent=1) + "\n")


if __name__ == "__main__":
    main()
