"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:

* fig5_motivation  -- paper Fig. 5 (exact published CCTs)
* fig7_cct_vs_msgsize -- paper Fig. 7(a-c)
* fig8_scalability -- paper Fig. 8(a-b)
* scheduler_bench  -- solve-time vs the paper's Gurobi claim
* kernel_bench     -- Pallas kernel microbenches (interpret mode)
* swot_ladder      -- optical scheduling modes on a real step's
                      collectives (EXPERIMENTS.md section 4.1)
"""

import sys


def main() -> None:
    from benchmarks import (
        fig5_motivation,
        fig7_cct_vs_msgsize,
        fig8_scalability,
        kernel_bench,
        scheduler_bench,
        swot_ladder,
    )

    modules = [
        fig5_motivation,
        fig7_cct_vs_msgsize,
        fig8_scalability,
        scheduler_bench,
        kernel_bench,
        swot_ladder,
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for module in modules:
        if only and only not in module.__name__:
            continue
        for name, us, note in module.run():
            print(f"{name},{us:.1f},{note}", flush=True)


if __name__ == "__main__":
    main()
