"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:

* fig5_motivation  -- paper Fig. 5 (exact published CCTs)
* fig7_cct_vs_msgsize -- paper Fig. 7(a-c)
* fig8_scalability -- paper Fig. 8(a-b)
* scheduler_bench  -- solve-time vs the paper's Gurobi claim
* kernel_bench     -- Pallas kernel microbenches (interpret mode)
* swot_ladder      -- optical scheduling modes on a real step's
                      collectives (EXPERIMENTS.md section 4.1)
* multi_tenant_bench -- concurrent collectives on a shared fabric
                      (tenants x planes x t_recfg sweep)

Usage: ``python benchmarks/run.py [module-substring] [--quick]``.
``--quick`` runs a single-cell smoke sweep per module that supports it
(CI uses this).
"""

import sys


def main() -> None:
    from benchmarks import (
        fig5_motivation,
        fig7_cct_vs_msgsize,
        fig8_scalability,
        kernel_bench,
        multi_tenant_bench,
        scheduler_bench,
        swot_ladder,
    )

    modules = [
        fig5_motivation,
        fig7_cct_vs_msgsize,
        fig8_scalability,
        scheduler_bench,
        kernel_bench,
        swot_ladder,
        multi_tenant_bench,
    ]
    args = [a for a in sys.argv[1:]]
    quick = "--quick" in args
    args = [a for a in args if a != "--quick"]
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for module in modules:
        if only and only not in module.__name__:
            continue
        if quick:
            import inspect

            if "quick" in inspect.signature(module.run).parameters:
                rows = module.run(quick=True)
            elif only or module is fig5_motivation:
                rows = module.run()  # cheap (or explicitly requested)
            else:
                continue  # no quick mode: skipped in CI smoke runs
        else:
            rows = module.run()
        for name, us, note in rows:
            print(f"{name},{us:.1f},{note}", flush=True)


if __name__ == "__main__":
    main()
