"""Optical-layer hillclimb: scheduling iterations on the MoE cell's
profiled collectives (the paper's own technique, then beyond it).

Ladder per collective of one qwen2-moe-a2.7b train step on 16 endpoints
x 4 optical planes (TPU-calibrated: 50 GB/s links, 200 us reconfig):

    strawman-ICR -> SWOT chain (paper) -> SWOT independent (beyond paper,
    pairwise only) -> 8 planes (provisioning sensitivity)

CCT per iteration; the EXPERIMENTS.md Perf log quotes this table.
"""

from repro.configs.base import shape_cell
from repro.configs.registry import get_config
from repro.core import (
    DependencyMode,
    OpticalFabric,
    TPU_V5E_LINK_BANDWIDTH,
    batch_evaluate,
    get_pattern,
    ideal_cct,
    prestage_for,
    strawman_instance,
    swot_greedy,
)
from repro.core.planner import profile_train_step
from repro.models.lm import _decoder_specs
from repro.sharding.rules import MeshContext, abstract_mesh_compat


def run() -> list[tuple[str, float, str]]:
    cfg = get_config("qwen2_moe_a2_7b").replace(
        moe_token_slice=True, sequence_parallel=True
    )
    mesh = abstract_mesh_compat((16, 16), ("data", "model"))
    ctx = MeshContext(mesh=mesh, dp_axes=("data",))
    cell = shape_cell("train_4k")
    specs = _decoder_specs(cfg, ctx)
    requests = profile_train_step(cfg, ctx, cell, specs)

    cells = []
    for req in requests:
        pattern = get_pattern(req.algorithm, req.n_nodes, req.size)
        for planes in (4, 8):
            fabric = prestage_for(
                OpticalFabric(
                    req.n_nodes,
                    planes,
                    bandwidth=TPU_V5E_LINK_BANDWIDTH,
                    t_recfg=200e-6,
                ),
                pattern,
            )
            cells.append((req, planes, fabric, pattern))

    # Every cell's strawman baseline in ONE batched IR pass (the timing
    # backend follows REPRO_IR_BACKEND: numpy default, jax/pallas opt-in).
    straw_ccts = batch_evaluate(
        [
            strawman_instance(fabric, pattern)
            for _, _, fabric, pattern in cells
        ]
    ).cct

    rows = []
    for (req, planes, fabric, pattern), straw in zip(cells, straw_ccts):
        straw = float(straw)
        chain = swot_greedy(fabric, pattern)
        entries = [
            ("strawman", straw),
            ("swot_chain", chain.cct),
        ]
        if req.algorithm == "pairwise_alltoall":
            indep = swot_greedy(
                fabric, pattern, mode=DependencyMode.INDEPENDENT
            )
            entries.append(("swot_independent", indep.cct))
        ideal = ideal_cct(fabric, pattern)
        for mode, cct in entries:
            rows.append(
                (
                    f"swot_ladder_{req.tag}_{planes}pl_{mode}",
                    cct * 1e6,
                    f"ideal={ideal * 1e6:.1f}us "
                    f"size={req.size / 1e6:.1f}MB "
                    f"vs_strawman={1 - cct / straw:+.1%}",
                )
            )
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
