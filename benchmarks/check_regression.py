"""CI benchmark-regression gate: diff emitted BENCH JSONs vs baselines.

``benchmarks/run.py --quick`` emits ``BENCH_sweep.json`` and
``BENCH_backends.json``; this script compares a fresh pair against the
committed baselines with a tolerance band and exits non-zero on
regression, so the BENCH_* numbers are enforced by the pipeline instead
of rotting silently.

What is gated (and why):

* **Deterministic sweep points** -- every ``BENCH_sweep.json`` point
  that is not a wall-clock timing row (CCTs, queueing delays,
  utilization: simulated quantities, identical on any machine).  A
  value drifting above baseline by more than the band fails.  This
  includes the Topology-Bypassing rows (``bypass_*_cct`` per-point CCTs
  and ``bypass_*_cct_ratio`` bypass/no-bypass ratios, which are <= 1 by
  the guarded pick): a bypass CCT reduction that shrinks past the band
  fails here, on top of the strict in-run gate ``ir_sweep.bypass_sweep``
  asserts at the documented high-``t_recfg`` point.
* **Higher-is-better points** -- deterministic rows named
  ``*_overlap_eff`` (attributed fraction of reconfiguration time the
  schedule hides behind transmission) and ``*_hit_rate`` (bypass
  steps served by relays): these fail when the current value falls
  *below* baseline by more than the band.
* **Rate points, absolute band** -- fraction-valued lower-is-better
  rows named ``*_miss_rate`` (per-tenant SLO deadline misses on the
  scale replay) and ``*_exposed_frac`` (per-site exposed share of
  reconfiguration time): deterministic simulated fractions in [0, 1],
  failed when the current value exceeds baseline by more than the band
  *absolutely* (baselines of exactly 0.0 stay gateable).
* **Speedup ratios** -- ``speedup_vs_numpy`` per backend from
  ``BENCH_backends.json``, the INDEPENDENT-grid
  ``speedup_vs_per_instance``, the fused-planner
  ``speedup_vs_per_step`` (fused ``lax.scan`` CHAIN planner vs the
  per-step numpy loop), and the runtime-scale
  ``multi_tenant_scale.speedup_vs_serial_path`` (warm memoized replay
  of the 10k-job heavy-tailed trace vs the legacy per-event planning
  path).  Ratios compare two timings from the SAME run on the SAME
  host, so they transfer across runner hardware where absolute
  microseconds do not.  A ratio falling below baseline by more than
  the band fails -- with the floor clamped to the benchmark's own
  in-run hard gate (>= 2x for the backend gates, >= 50x for the
  runtime-scale gate), so a baseline captured on a fast host can
  never fail a slower runner that still clears the gate.
* **Throughput rows, wide band** -- ``*_events_per_sec`` and
  ``*_speedup`` sweep rows are wall-clock derived, so absolute values
  move with runner hardware; they get a deliberately wide
  higher-is-better band (fail only below 10%% of baseline) that still
  catches an order-of-magnitude collapse -- e.g. the hot path
  silently falling back to per-event planning.

What is deliberately NOT gated:

* absolute wall-clock rows (``*_wall_time``, ``ir_sweep_*``,
  ``indep_grid_*``, ``ir_backend_*``, ``fused_grid_*`` microsecond
  columns, including the ``*_compile`` cold-start rows and the
  ``*_us`` phase rows) -- runner hardware varies run to run;
* the ``pallas`` backend ratio -- interpret mode on CPU times the
  interpreter, not the kernel.

A point present in the baseline but missing from the current run fails
too (a silently dropped gate is itself rot); new points are reported
but pass, since they land together with their regenerated baseline.

Usage (CI runs exactly this)::

    python benchmarks/check_regression.py \
        --baseline /tmp/bench-baseline --current . [--tolerance 0.25]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parent.parent / "src")
)

from repro.obs import get_logger  # noqa: E402

log = get_logger("check_regression")

# Sweep rows whose us_per_call is a wall-clock measurement (machine
# dependent): excluded from the deterministic-point comparison.  The
# ``_us$`` suffix covers the per-phase timing rows.
_TIMING_ROW = re.compile(
    r"(wall_time|ir_sweep_|indep_grid_|ir_backend_|fused_grid_"
    r"|_solve_time|_us$)"
)
# Deterministic sweep rows where LARGER is better (overlap efficiency,
# bypass/cache hit rate): gated on falling below baseline instead of
# rising above it.
_HIGHER_BETTER = re.compile(r"(overlap_eff|hit_rate|overlap_gain)$")
# Fraction-valued lower-is-better rows (SLO deadline miss rates,
# per-site exposed-reconfiguration fractions): values live in [0, 1]
# and baselines are legitimately 0.0, so the band is *absolute* -- the
# current rate may not exceed baseline + tolerance.  Checked before the
# higher-is-better rule (``deadline_miss_rate`` must not fall through
# to the relative rules).
_RATE_ROW = re.compile(r"(miss_rate|exposed_frac)$")
# Wall-clock-derived throughput rows (events/sec, speedup ratios):
# higher is better, but absolute values track runner hardware, so the
# band is deliberately wide -- only an order-of-magnitude collapse
# (below 10% of baseline) fails.
_WIDE_BAND_ROW = re.compile(r"(events_per_sec|_speedup)$")
_WIDE_BAND = 0.90
# Backends whose speedup ratio is not meaningful on CI hosts.
_UNGATED_BACKENDS = frozenset({"pallas"})

# Hard floors the benchmarks themselves assert in-run (ir_sweep's >= 2x
# gates).  The band floor is clamped to never exceed these: a baseline
# captured on a fast host must not make a slower runner fail while it
# still clears the benchmark's own gate -- but a current run whose JSON
# somehow records a sub-gate ratio (e.g. the in-bench assert was
# deleted) still fails here.
_RATIO_HARD_GATES = {
    "backend_speedup:jax": 2.0,
    "independent_grid_speedup": 2.0,
    "fused_grid_speedup": 2.0,
    "mt_scale_speedup": 50.0,
}

SWEEP_NAME = "BENCH_sweep.json"
BACKENDS_NAME = "BENCH_backends.json"


def _load(path: pathlib.Path) -> dict:
    with open(path) as fh:
        return json.load(fh)


def _sweep_points(payload: dict) -> dict[str, float]:
    return {
        pt["name"]: float(pt["us_per_call"])
        for pt in payload.get("points", [])
        if not _TIMING_ROW.search(pt["name"])
    }


def _speedup_ratios(payload: dict) -> dict[str, float]:
    ratios: dict[str, float] = {}
    for name, entry in payload.get("backends", {}).items():
        if name in _UNGATED_BACKENDS or "speedup_vs_numpy" not in entry:
            continue
        ratios[f"backend_speedup:{name}"] = float(
            entry["speedup_vs_numpy"]
        )
    grid = payload.get("independent_grid", {})
    if "speedup_vs_per_instance" in grid:
        ratios["independent_grid_speedup"] = float(
            grid["speedup_vs_per_instance"]
        )
    fused = payload.get("fused_grid", {})
    if "speedup_vs_per_step" in fused:
        ratios["fused_grid_speedup"] = float(fused["speedup_vs_per_step"])
    scale = payload.get("multi_tenant_scale", {})
    if "speedup_vs_serial_path" in scale:
        ratios["mt_scale_speedup"] = float(
            scale["speedup_vs_serial_path"]
        )
    return ratios


def compare(
    baseline_dir: pathlib.Path,
    current_dir: pathlib.Path,
    tolerance: float,
) -> list[str]:
    """Return a list of human-readable failures (empty = pass)."""
    failures: list[str] = []

    base_sweep = _sweep_points(_load(baseline_dir / SWEEP_NAME))
    cur_sweep = _sweep_points(_load(current_dir / SWEEP_NAME))
    for name, base in sorted(base_sweep.items()):
        if name not in cur_sweep:
            failures.append(f"sweep point {name!r} missing from current run")
            continue
        cur = cur_sweep[name]
        if _RATE_ROW.search(name):
            if cur > base + tolerance:
                failures.append(
                    f"rate point {name!r} regressed: {cur:.3f} vs "
                    f"baseline {base:.3f} (+{cur - base:.3f} absolute, "
                    f"band is {tolerance:.2f})"
                )
        elif _WIDE_BAND_ROW.search(name):
            if base > 0 and cur < base * (1.0 - _WIDE_BAND):
                failures.append(
                    f"throughput point {name!r} collapsed: {cur:.1f} vs "
                    f"baseline {base:.1f} ({cur / base - 1.0:.0%}, "
                    f"wide band is {_WIDE_BAND:.0%})"
                )
        elif _HIGHER_BETTER.search(name):
            if base > 0 and cur < base * (1.0 - tolerance):
                failures.append(
                    f"sweep point {name!r} regressed: {cur:.3f} vs "
                    f"baseline {base:.3f} ({cur / base - 1.0:.0%}, "
                    f"higher-is-better band is {tolerance:.0%})"
                )
        elif base > 0 and cur > base * (1.0 + tolerance):
            failures.append(
                f"sweep point {name!r} regressed: {cur:.3f} vs baseline "
                f"{base:.3f} (+{cur / base - 1.0:.0%}, band is "
                f"{tolerance:.0%})"
            )
    for name in sorted(set(cur_sweep) - set(base_sweep)):
        log.info(f"note: new sweep point {name!r} (no baseline yet)")

    base_ratio = _speedup_ratios(_load(baseline_dir / BACKENDS_NAME))
    cur_ratio = _speedup_ratios(_load(current_dir / BACKENDS_NAME))
    for name, base in sorted(base_ratio.items()):
        if name not in cur_ratio:
            failures.append(f"ratio {name!r} missing from current run")
            continue
        cur = cur_ratio[name]
        floor = base * (1.0 - tolerance)
        if name in _RATIO_HARD_GATES:
            floor = min(floor, _RATIO_HARD_GATES[name])
        if base > 0 and cur < floor:
            failures.append(
                f"throughput ratio {name!r} regressed: {cur:.2f}x vs "
                f"baseline {base:.2f}x (floor {floor:.2f}x, band is "
                f"{tolerance:.0%})"
            )
    for name in sorted(set(cur_ratio) - set(base_ratio)):
        log.info(f"note: new ratio {name!r} (no baseline yet)")

    n_checked = len(base_sweep) + len(base_ratio)
    # The verdict is the script's contract (CI greps it): data channel.
    log.data(
        f"checked {len(base_sweep)} sweep points + {len(base_ratio)} "
        f"throughput ratios against {baseline_dir} "
        f"(band {tolerance:.0%}): "
        + ("PASS" if not failures else f"{len(failures)} FAILURE(S)")
    )
    assert n_checked > 0, "baselines contained nothing to check"
    return failures


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--baseline",
        type=pathlib.Path,
        required=True,
        help="directory holding the committed baseline BENCH_*.json",
    )
    parser.add_argument(
        "--current",
        type=pathlib.Path,
        required=True,
        help="directory holding the freshly emitted BENCH_*.json",
    )
    parser.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="relative regression band (default 0.25 = 25%%)",
    )
    args = parser.parse_args(argv)
    failures = compare(args.baseline, args.current, args.tolerance)
    for failure in failures:
        log.warning(f"REGRESSION: {failure}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
