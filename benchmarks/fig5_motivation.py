"""Paper Fig. 5: the motivating example, reproduced exactly.

8-node Rabenseifner AllReduce of a 40 MB collective on 2 OCS planes,
400 Gbps links, 200 us reconfiguration:

* naive ICR (strawman):        1500 us   (paper: 1500 us, 800 us overhead)
* SWOT overlap (MILP optimal): 1200 us   (paper's illustrated schedule)
* ideal (no optics):            700 us
"""

import time

from repro.core import (
    FIG5_LINK_BANDWIDTH,
    OpticalFabric,
    ideal_cct,
    prestage_for,
    rabenseifner_allreduce,
    solve_milp,
    strawman_icr,
    swot_greedy,
)


def run() -> list[tuple[str, float, str]]:
    pattern = rabenseifner_allreduce(8, 40e6)
    fabric = prestage_for(
        OpticalFabric(8, 2, bandwidth=FIG5_LINK_BANDWIDTH, t_recfg=200e-6),
        pattern,
    )
    rows = []
    t0 = time.perf_counter()
    straw = strawman_icr(fabric, pattern)
    rows.append(
        (
            "fig5_strawman_icr",
            straw.cct * 1e6,
            f"paper=1500us reconfigs={straw.total_reconfigurations}",
        )
    )
    milp = solve_milp(fabric, pattern)
    rows.append(
        (
            "fig5_swot_milp",
            milp.schedule.cct * 1e6,
            f"paper=1200us gap={milp.mip_gap:.1e}",
        )
    )
    greedy = swot_greedy(fabric, pattern)
    rows.append(("fig5_swot_greedy", greedy.cct * 1e6, "matches MILP"))
    rows.append(("fig5_ideal", ideal_cct(fabric, pattern) * 1e6, "no optics"))
    rows.append(
        (
            "fig5_wall_time",
            (time.perf_counter() - t0) * 1e6,
            "bench runtime",
        )
    )
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")
