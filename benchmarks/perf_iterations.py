"""Perf hillclimb driver: hypothesis -> change -> re-lower -> measure.

Applies cumulative config changes to a chosen (arch x shape x mesh) cell,
re-runs the dry-run compile, and records the three roofline terms per
variant in ``artifacts/perf/``.  The EXPERIMENTS.md section Perf log is
generated from these artifacts.

Must run under the 512-device flag, so invoke via:

    PYTHONPATH=src python -m benchmarks.perf_iterations --cell moe
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

import argparse
import json

from repro.obs import get_logger

log = get_logger("perf_iterations")

# Cumulative optimization ladders per hillclimbed cell.  Each entry:
# (variant_name, config_overrides, hypothesis)
LADDERS = {
    "moe": {
        "arch": "qwen2_moe_a2_7b",
        "shape": "train_4k",
        "multi_pod": False,
        "steps": [
            (
                "baseline",
                {},
                "paper-faithful EP MoE: activations replicated over the "
                "model axis; every EP rank dispatches all dp-local tokens",
            ),
            (
                "+token_slice",
                {"moe_token_slice": True},
                "each EP rank dispatches 1/16 of the tokens: MoE dispatch "
                "FLOPs and a2a buffers shrink ~16x; compute term drops",
            ),
            # NOTE: the +seq_parallel artifact was measured before the
            # seq-sharded MoE fusion existed (naive SP: GSPMD all-gathers
            # the residual around every MoE layer).  It is kept as the
            # recorded refuted iteration; re-running with --force would
            # measure the fused path instead.
            (
                "+seq_parallel",
                {"moe_token_slice": True, "sequence_parallel": True},
                "residual stream sharded over model: norm/residual traffic "
                "and layer-boundary checkpoints /16; memory term drops",
            ),
            (
                "+sp_fused_moe",
                {"moe_token_slice": True, "sequence_parallel": True},
                "REACTION to refuted +seq_parallel: the SP shard IS the EP "
                "token slice, so the MoE consumes the seq-sharded residual "
                "directly (no per-layer gather/reassembly) and expert "
                "matmuls run in bf16; collective term back down, fits HBM",
            ),
            (
                "+ts_grad_accum4",
                {"moe_token_slice": True, "grad_accum": 4},
                "alternative fit path: keep token_slice WITHOUT SP (avoid "
                "its attention-path collectives) and fit HBM via 4 "
                "microbatches instead -- activations /4, bound stays near "
                "the +token_slice optimum",
            ),
        ],
    },
    "llama4": {
        "arch": "llama4_scout_17b_16e",
        "shape": "train_4k",
        "multi_pod": False,
        "steps": [
            (
                "baseline",
                {},
                "109B MoE at 256 chips: residual checkpoints (48 x 671MB) "
                "+ replicated MoE dispatch blow past 16 GB HBM",
            ),
            (
                "+seq_parallel",
                {"sequence_parallel": True},
                "checkpointed residuals shard over model: -30GB device "
                "memory; memory term drops",
            ),
            (
                "+token_slice",
                {"sequence_parallel": True, "moe_token_slice": True},
                "EP dispatch de-duplicated: compute term ~/10, a2a smaller",
            ),
            (
                "+bf16_gather",
                {"sequence_parallel": True, "moe_token_slice": True,
                 "vocab_pad_multiple": 128},
                "expert weights cast to bf16 BEFORE the per-layer FSDP "
                "all-gather: the dominant collective (weight gathers over "
                "data) halves",
            ),
            (
                "+grad_accum4",
                {"sequence_parallel": True, "moe_token_slice": True,
                 "grad_accum": 4},
                "4 microbatches: per-microbatch activations and attention "
                "residuals /4; device memory fits 16 GB HBM",
            ),
            (
                "+ts_only_ga4",
                {"moe_token_slice": True, "grad_accum": 4},
                "drop SP entirely (its seq-resharding lowers to "
                "collective-permute storms: 315GB/73k permutes per step) "
                "and fit memory via microbatching instead; collective term "
                "should collapse to grads + EP a2a + FSDP gathers",
            ),
        ],
    },
    "prefill": {
        "arch": "qwen3_4b",
        "shape": "prefill_32k",
        "multi_pod": False,
        "steps": [
            (
                "baseline",
                {},
                "32k prefill: full-KV blocked attention computes every "
                "(q, kv) block and masks -- ~2x minimal attention FLOPs",
            ),
            (
                "+xla_skip",
                {"attention_impl": "xla_skip"},
                "trace-time causal block skipping: ~half the attention "
                "FLOPs and score traffic at 32k",
            ),
            (
                "+probs_bf16",
                {
                    "attention_impl": "xla_skip",
                    "attn_probs_bf16": True,
                },
                "bf16 PV matmul: score-tensor traffic halves again",
            ),
            (
                "+q_block_1024",
                {"attention_impl": "xla_skip", "attn_q_block": 1024,
                 "attn_kv_block": 1024},
                "halve the number of unrolled q/kv blocks at 32k: less "
                "per-block overhead and fewer live backward buffers",
            ),
            (
                "+q_block_2048",
                {"attention_impl": "xla_skip", "attn_q_block": 2048,
                 "attn_kv_block": 2048},
                "again: 16 q blocks of 2048; check for diminishing returns "
                "(stop rule: <5% on the dominant term)",
            ),
        ],
    },
}
# The +bf16_gather variant carries a no-op override (vocab_pad_multiple
# already defaults to 128) purely to distinguish its artifact from
# +token_slice: the actual change is the bf16-before-gather code fix in
# repro.models.moe (see EXPERIMENTS.md section Perf).

ARTIFACT_DIR = os.path.join("artifacts", "perf")


def run_ladder(name: str, force: bool = False) -> list[dict]:
    from repro.configs.base import shape_cell
    from repro.configs.registry import get_config
    from repro.launch.dryrun import run_cell

    spec = LADDERS[name]
    cfg0 = get_config(spec["arch"])
    cell = shape_cell(spec["shape"])
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    records = []
    for variant, overrides, hypothesis in spec["steps"]:
        path = os.path.join(
            ARTIFACT_DIR, f"{name}__{variant.replace('+', '')}.json"
        )
        if os.path.exists(path) and not force:
            with open(path) as f:
                records.append(json.load(f))
            continue
        cfg = cfg0.replace(**overrides) if overrides else cfg0
        record = run_cell(cfg, cell, spec["multi_pod"])
        record["variant"] = variant
        record["hypothesis"] = hypothesis
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        records.append(record)
    return records


def report(records: list[dict]) -> None:
    prev = None
    for rec in records:
        r = rec["roofline"]
        line = (
            f"{rec['variant']:14s} dev={rec['device_bytes'] / 2**30:7.2f}GiB "
            f"fits={str(rec['fits_hbm']):5s} "
            f"comp={r['compute_s'] * 1e3:9.1f}ms "
            f"mem={r['memory_s'] * 1e3:9.1f}ms "
            f"coll={r['collective_s'] * 1e3:7.1f}ms "
            f"dom={r['dominant']:10s} roof%={r['roofline_fraction']:6.2%}"
        )
        if prev is not None:
            db = r[prev["dominant"] + "_s"] / prev[prev["dominant"] + "_s"]
            line += f"  (dominant term x{db:.2f})"
        log.info(line)
        prev = r


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--cell", choices=list(LADDERS) + ["all"],
                        default="all")
    parser.add_argument("--force", action="store_true")
    args = parser.parse_args()
    names = list(LADDERS) if args.cell == "all" else [args.cell]
    for name in names:
        log.info(f"=== perf ladder: {name} ===")
        report(run_ladder(name, force=args.force))
        log.info("")


if __name__ == "__main__":
    main()
