"""End-to-end training driver: ~100M-parameter LM with checkpoint/restart.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py --steps 5 --preset tiny

Demonstrates the full stack: synthetic pipeline -> sharded train step
(grad accumulation, AdamW, clipping) -> atomic checkpoints -> resume.
Re-running the same command continues from the latest checkpoint.
"""

import argparse

import jax

from repro.configs.base import ArchConfig, ShapeCell
from repro.data.pipeline import SyntheticPipeline
from repro.models.common import param_count
from repro.models.lm import build_model
from repro.obs import get_logger
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import single_device_context
from repro.train.checkpoint import latest_step, restore_checkpoint
from repro.train.ft import run_with_restarts
from repro.train.loop import Trainer

log = get_logger("train_100m")

PRESETS = {
    # ~100M params: 12L x 640d, SwiGLU 2560, 10 heads, 32k vocab.
    "100m": ArchConfig(
        name="repro_100m",
        family="dense",
        n_layers=12,
        d_model=640,
        n_heads=10,
        n_kv_heads=10,
        d_ff=2560,
        vocab_size=32000,
        vocab_pad_multiple=64,
        tie_embeddings=True,
        attn_q_block=128,
        attn_kv_block=128,
    ),
    "tiny": ArchConfig(
        name="repro_tiny",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=1024,
        vocab_pad_multiple=64,
        tie_embeddings=True,
        attn_q_block=64,
        attn_kv_block=64,
    ),
}


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--steps", type=int, default=300)
    parser.add_argument("--preset", choices=PRESETS, default="100m")
    parser.add_argument("--batch", type=int, default=8)
    parser.add_argument("--seq", type=int, default=256)
    parser.add_argument("--grad-accum", type=int, default=1)
    parser.add_argument("--ckpt-dir", default="artifacts/train_100m")
    args = parser.parse_args()

    cfg = PRESETS[args.preset]
    ctx = single_device_context()
    model = build_model(cfg, ctx)
    log.info(f"{cfg.name}: {param_count(model.specs) / 1e6:.1f}M parameters")
    cell = ShapeCell("train", "train", args.seq, args.batch)
    trainer = Trainer(
        model=model,
        cell=cell,
        opt_cfg=AdamWConfig(
            peak_lr=3e-4, warmup_steps=20, total_steps=args.steps
        ),
        grad_accum=args.grad_accum,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=25,
    )
    resumed = latest_step(args.ckpt_dir)
    if resumed is not None:
        log.info(f"resuming from checkpoint at step {resumed}")
    state, restarts = run_with_restarts(
        trainer,
        lambda: SyntheticPipeline(cfg, cell, seed=0),
        args.ckpt_dir,
        target_steps=args.steps,
    )
    # Report the tail of the loss curve.
    pipeline = SyntheticPipeline(cfg, cell, seed=0)
    state2, data_state = restore_checkpoint(args.ckpt_dir, model)
    pipeline.restore(data_state)
    _, history = trainer.run(state2, pipeline, n_steps=3, log_every=1)
    log.info(
        f"finished at step {int(state.step)} (restarts={restarts}); "
        f"latest losses: {[round(h['loss'], 4) for h in history]}"
    )


if __name__ == "__main__":
    main()
