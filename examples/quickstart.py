"""Quickstart: schedule a collective with SWOT, then train a tiny model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.base import ShapeCell
from repro.configs.registry import smoke_config
from repro.core import (
    CollectiveRequest,
    OpticalFabric,
    SwotShim,
)
from repro.data.pipeline import SyntheticPipeline
from repro.models.lm import build_model
from repro.obs import get_logger
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import single_device_context
from repro.train.loop import Trainer, init_train_state

log = get_logger("quickstart")


def main() -> None:
    # --- 1. SWOT: schedule a collective on an optical fabric ------------
    log.info("=== SWOT optical scheduling ===")
    shim = SwotShim(OpticalFabric(n_nodes=16, n_planes=4))
    req = CollectiveRequest(
        "rabenseifner_allreduce", 16, 25e6, "dp_grad_sync"
    )
    shim.install([req])  # Phase 1: pre-configuration
    plan = shim.intercept(req)  # Phase 2: runtime interception
    log.info(plan.schedule.timeline())
    log.info(
        f"SWOT {plan.cct * 1e6:.0f}us vs strawman "
        f"{plan.strawman_cct * 1e6:.0f}us ({plan.vs_strawman:+.1%})\n"
    )

    # --- 2. Train a reduced model for a few steps ------------------------
    log.info("=== training (reduced qwen3 config, CPU) ===")
    ctx = single_device_context()
    cfg = smoke_config("qwen3_4b")
    model = build_model(cfg, ctx)
    cell = ShapeCell("quickstart", "train", 64, 4)
    trainer = Trainer(
        model=model,
        cell=cell,
        opt_cfg=AdamWConfig(peak_lr=1e-3, warmup_steps=5, total_steps=40),
    )
    state = init_train_state(model, jax.random.PRNGKey(0))
    pipeline = SyntheticPipeline(cfg, cell, seed=0)
    state, history = trainer.run(state, pipeline, n_steps=20, log_every=5)
    for h in history:
        log.info(f"step {h['step']:3d}  loss {h['loss']:.4f}")


if __name__ == "__main__":
    main()
