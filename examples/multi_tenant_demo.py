"""Two tenants sharing one optical fabric, end to end.

A dense (qwen3-4b) and an MoE (qwen2-moe-a2.7b) training job issue their
collectives concurrently on the same 8-node x 4-plane fabric.  The
``repro.runtime`` arbiter leases planes between them, shrinking and
growing leases at step boundaries; the replay prints per-job realized
CCT, queueing delay, and fabric utilization -- then contrasts the same
trace on a serial (one-collective-at-a-time) fabric.

``--trace out.json`` records the replay with ``repro.obs.ChromeTracer``
and writes Chrome trace-event JSON: load it at https://ui.perfetto.dev
to see per-plane transmit/reconfigure spans, lease churn, and queue
depth over simulated time.  Narrative output goes through the
``repro.obs`` logger (``REPRO_LOG=quiet`` silences it, ``=json``
renders JSON lines).

    PYTHONPATH=src python examples/multi_tenant_demo.py [--trace out.json]
"""

import argparse
import contextlib

from repro.configs.registry import get_config
from repro.core import OpticalFabric, get_pattern, swot_schedule
from repro.obs import ChromeTracer, get_logger
from repro.runtime import arch_request_mix, poisson_trace, replay

N_NODES = 8
N_PLANES = 4
SIZE_SCALE = 1 / 256  # demo-scale messages (full DP syncs are GBs)

log = get_logger("multi_tenant_demo")


def scaled_mix(name: str):
    mix = arch_request_mix(
        get_config(name), n_nodes=N_NODES, tokens_per_step=16_384
    )
    return [
        type(r)(r.algorithm, r.n_nodes, r.size * SIZE_SCALE, r.tag)
        for r in mix
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--trace",
        metavar="OUT.json",
        default=None,
        help="write the replay as Chrome trace-event JSON (Perfetto)",
    )
    args = parser.parse_args()
    fabric = OpticalFabric(N_NODES, N_PLANES)
    tenants = [
        ("qwen3_4b", scaled_mix("qwen3_4b")),
        ("qwen2_moe_a2_7b", scaled_mix("qwen2_moe_a2_7b")),
    ]
    trace = poisson_trace(
        tenants,
        rate=600.0,  # heavy enough that collectives genuinely overlap
        horizon=0.05,
        seed=7,
        priorities={"qwen3_4b": 1},  # dense job preempts queue order
    )
    log.info(
        f"{len(trace)} collectives from {len(tenants)} tenants on "
        f"{N_NODES} nodes x {N_PLANES} planes\n"
    )

    # Context-managed tracer: the trace file is written when the block
    # exits, including on a mid-replay crash (partial traces still load
    # in Perfetto).
    with contextlib.ExitStack() as stack:
        tracer = None
        if args.trace:
            tracer = stack.enter_context(ChromeTracer(path=args.trace))
        report = replay(trace, fabric, method="greedy", tracer=tracer)
    log.info("== shared fabric (arbitrated) ==")
    log.info(report.summary())

    log.info("\nper-job timeline (first 10):")
    for r in report.records[:10]:
        log.info(
            f"  t={r.arrival * 1e3:7.2f}ms {r.tag:32s} "
            f"wait={r.queueing_delay * 1e6:8.1f}us "
            f"cct={r.cct * 1e6:8.1f}us "
            f"planes={r.planes_min}..{r.planes_max}"
        )

    # Serial baseline: same jobs, one at a time, whole fabric each.
    serial_busy = 0.0
    for spec in trace:
        pattern = get_pattern(
            spec.request.algorithm, spec.request.n_nodes, spec.request.size
        )
        schedule, _ = swot_schedule(
            fabric.prestaged(pattern.steps[0].config),
            pattern,
            method="greedy",
        )
        serial_busy += schedule.cct
    last_arrival = max(s.arrival for s in trace)
    serial_makespan = max(last_arrival, serial_busy)
    log.info(
        f"\n== serial fabric (one collective at a time) ==\n"
        f"sum of solo CCTs {serial_busy * 1e3:.2f} ms "
        f"(makespan >= {serial_makespan * 1e3:.2f} ms vs arbitrated "
        f"{report.makespan * 1e3:.2f} ms)"
    )

    if tracer is not None:
        log.info(
            f"\nwrote {len(tracer.events)} trace events to {args.trace} "
            "(open at https://ui.perfetto.dev)"
        )


if __name__ == "__main__":
    main()
