"""Serve a small model with batched requests.

    PYTHONPATH=src python examples/serve_batched.py
"""

import jax

from repro.configs.registry import smoke_config
from repro.models.lm import build_model
from repro.obs import get_logger
from repro.serve.engine import Request, ServeEngine
from repro.sharding.rules import single_device_context

log = get_logger("serve_batched")


def main() -> None:
    ctx = single_device_context()
    cfg = smoke_config("qwen2_1_5b")
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=128)

    requests = [
        Request(prompt=[12, 45, 7, 99], max_new_tokens=12),
        Request(prompt=[3, 14, 15, 92, 65], max_new_tokens=8),
        Request(prompt=[42], max_new_tokens=16),
        Request(prompt=[8, 8, 8], max_new_tokens=10),
    ]
    completions = engine.generate(requests)
    for i, c in enumerate(completions):
        log.info(f"request {i}: prompt={c.prompt} -> tokens={c.tokens}")


if __name__ == "__main__":
    main()
