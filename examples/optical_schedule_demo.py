"""Per-iteration optical plan for a production MoE training step.

Profiles the collectives one optimizer step of qwen2-moe-a2.7b will issue
on the 16x16 production mesh (DP gradient sync, TP activation
all-reduces, EP all-to-alls), schedules each on the optical fabric with
SWOT, and prints the timelines + per-iteration optical report --
the paper's Phase 1/Phase 2 flow end to end.  Closes with a batched
what-if sweep over reconfiguration latencies through the array IR
(`repro.core.batch_evaluate`) on a selectable timing backend.

    PYTHONPATH=src python examples/optical_schedule_demo.py \
        [--backend numpy|jax|pallas]
"""

import argparse

from repro.configs.base import shape_cell
from repro.configs.registry import get_config
from repro.core import (
    OpticalFabric,
    SwotShim,
    TPU_V5E_LINK_BANDWIDTH,
    batch_evaluate,
    strawman_instance,
)
from repro.core.planner import profile_train_step
from repro.models.lm import _decoder_specs  # spec-only; no allocation
from repro.sharding.rules import MeshContext, abstract_mesh_compat


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--backend",
        choices=("numpy", "jax", "pallas"),
        default=None,
        help="IR timing backend for the what-if sweep "
        "(default: REPRO_IR_BACKEND env, else numpy)",
    )
    args = parser.parse_args()
    cfg = get_config("qwen2_moe_a2_7b")
    # AbstractMesh: the planner only needs mesh *shapes*; no devices.
    mesh = abstract_mesh_compat((16, 16), ("data", "model"))
    ctx = MeshContext(mesh=mesh, dp_axes=("data",))
    specs = _decoder_specs(cfg, ctx)
    cell = shape_cell("train_4k")

    requests = profile_train_step(cfg, ctx, cell, specs)
    print(f"profiled {len(requests)} collective signatures for one "
          f"{cfg.name} train step on 16x16:")
    for r in requests:
        print(f"  {r.tag:28s} {r.algorithm:24s} n={r.n_nodes:3d} "
              f"{r.size / 1e6:10.2f} MB/node")

    # TPU-calibrated optical fabric: 16 endpoints x 4 OCS planes.
    fabric = OpticalFabric(
        n_nodes=16,
        n_planes=4,
        bandwidth=TPU_V5E_LINK_BANDWIDTH,
        t_recfg=200e-6,
    )
    shim = SwotShim(fabric)
    shim.install(requests)  # Phase 1
    for r in requests:  # Phase 2: one training iteration
        shim.intercept(r)
    print()
    print(shim.iteration_report())
    print()
    for plan in shim.plans:
        print(f"--- {plan.pattern.name} "
              f"{plan.pattern.total_volume / 1e6:.1f}MB/node ---")
        print(plan.schedule.timeline())
        print()

    # What-if sweep: how does lockstep-ICR CCT move with OCS reconfig
    # latency?  One batched array-IR pass evaluates every (collective,
    # t_recfg) cell -- no per-instance schedule objects.
    recfgs = (25e-6, 100e-6, 200e-6, 800e-6)
    cells = [
        strawman_instance(
            OpticalFabric(
                n_nodes=plan.fabric.n_nodes,
                n_planes=plan.fabric.n_planes,
                bandwidth=plan.fabric.bandwidth,
                t_recfg=t_recfg,
            ),
            plan.pattern,
            prestage=True,
        )
        for plan in shim.plans
        for t_recfg in recfgs
    ]
    ccts = batch_evaluate(cells, backend=args.backend).cct
    print(
        f"strawman CCT vs t_recfg ({len(cells)} cells, one IR pass, "
        f"backend={args.backend or 'default'}):"
    )
    k = 0
    for plan in shim.plans:
        points = "  ".join(
            f"{recfgs[r] * 1e6:.0f}us->{ccts[k + r] * 1e6:.0f}us"
            for r in range(len(recfgs))
        )
        print(f"  {plan.pattern.name:24s} {points}")
        k += len(recfgs)


if __name__ == "__main__":
    main()
