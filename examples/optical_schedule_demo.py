"""Per-iteration optical plan for a production MoE training step.

Profiles the collectives one optimizer step of qwen2-moe-a2.7b will issue
on the 16x16 production mesh (DP gradient sync, TP activation
all-reduces, EP all-to-alls), schedules each on the optical fabric with
SWOT, and prints the timelines + per-iteration optical report --
the paper's Phase 1/Phase 2 flow end to end.  Closes with a batched
what-if sweep over reconfiguration latencies through the array IR
(`repro.core.batch_evaluate`) on a selectable timing backend, attributed
per cell: ``attribution=True`` splits each CCT into transmit / exposed
vs. hidden reconfiguration / idle, and the printed *overlap efficiency*
is the fraction of reconfiguration time hidden behind transmission.

``--bypass`` appends a Topology-Bypassing section: the EP all-to-all is
re-planned with relay candidates up to ``--bypass-depth`` hops
(`repro.core.bypass`), printing the relay timeline and the CCT
reduction vs the no-bypass greedy across the ``t_recfg`` axis.

``--trace out.json`` exports the planned timelines as Chrome
trace-event JSON (one thread row per plane; plans laid out
back-to-back), loadable at https://ui.perfetto.dev.

    PYTHONPATH=src python examples/optical_schedule_demo.py \
        [--backend numpy|jax|pallas] [--bypass] [--bypass-depth H] \
        [--trace out.json]
"""

import argparse

from repro.configs.base import shape_cell
from repro.configs.registry import get_config
from repro.core import (
    OpticalFabric,
    SwotShim,
    TPU_V5E_LINK_BANDWIDTH,
    batch_evaluate,
    pairwise_alltoall,
    strawman_instance,
)
from repro.core.greedy import swot_greedy_chain
from repro.core.planner import profile_train_step
from repro.models.lm import _decoder_specs  # spec-only; no allocation
from repro.obs import ChromeTracer, get_logger, trace_schedule
from repro.sharding.rules import MeshContext, abstract_mesh_compat

log = get_logger("optical_schedule_demo")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--backend",
        choices=("numpy", "jax", "pallas"),
        default=None,
        help="IR timing backend for the what-if sweep "
        "(default: REPRO_IR_BACKEND env, else numpy)",
    )
    parser.add_argument(
        "--bypass",
        action="store_true",
        help="add the Topology-Bypassing section (relay-routing the EP "
        "all-to-all over installed circuits)",
    )
    parser.add_argument(
        "--bypass-depth",
        type=int,
        default=2,
        metavar="H",
        help="maximum relay hops for bypass candidates (default 2)",
    )
    parser.add_argument(
        "--trace",
        metavar="OUT.json",
        default=None,
        help="write the planned timelines as Chrome trace-event JSON",
    )
    args = parser.parse_args()
    cfg = get_config("qwen2_moe_a2_7b")
    # AbstractMesh: the planner only needs mesh *shapes*; no devices.
    mesh = abstract_mesh_compat((16, 16), ("data", "model"))
    ctx = MeshContext(mesh=mesh, dp_axes=("data",))
    specs = _decoder_specs(cfg, ctx)
    cell = shape_cell("train_4k")

    requests = profile_train_step(cfg, ctx, cell, specs)
    log.info(f"profiled {len(requests)} collective signatures for one "
             f"{cfg.name} train step on 16x16:")
    for r in requests:
        log.info(f"  {r.tag:28s} {r.algorithm:24s} n={r.n_nodes:3d} "
                 f"{r.size / 1e6:10.2f} MB/node")

    # TPU-calibrated optical fabric: 16 endpoints x 4 OCS planes.
    fabric = OpticalFabric(
        n_nodes=16,
        n_planes=4,
        bandwidth=TPU_V5E_LINK_BANDWIDTH,
        t_recfg=200e-6,
    )
    shim = SwotShim(fabric)
    shim.install(requests)  # Phase 1
    for r in requests:  # Phase 2: one training iteration
        shim.intercept(r)
    log.info("")
    log.info(shim.iteration_report())
    log.info("")
    for plan in shim.plans:
        log.info(f"--- {plan.pattern.name} "
                 f"{plan.pattern.total_volume / 1e6:.1f}MB/node ---")
        log.info(plan.schedule.timeline())
        log.info("")

    if args.trace:
        tracer = ChromeTracer(process_name="demo plans")
        t0 = 0.0
        for plan in shim.plans:
            trace_schedule(plan.schedule, tracer, t0=t0)
            t0 += plan.schedule.cct
        tracer.write(args.trace)
        log.info(
            f"wrote {len(tracer.events)} trace events to {args.trace} "
            "(open at https://ui.perfetto.dev)"
        )
        log.info("")

    # What-if sweep: how does lockstep-ICR CCT move with OCS reconfig
    # latency?  One batched array-IR pass evaluates every (collective,
    # t_recfg) cell -- with attribution=True splitting each CCT into
    # components, no per-instance schedule objects.
    recfgs = (25e-6, 100e-6, 200e-6, 800e-6)
    cells = [
        strawman_instance(
            OpticalFabric(
                n_nodes=plan.fabric.n_nodes,
                n_planes=plan.fabric.n_planes,
                bandwidth=plan.fabric.bandwidth,
                t_recfg=t_recfg,
            ),
            plan.pattern,
            prestage=True,
        )
        for plan in shim.plans
        for t_recfg in recfgs
    ]
    result = batch_evaluate(cells, backend=args.backend, attribution=True)
    ccts = result.cct
    eff = result.attribution.overlap_efficiency
    log.info(
        f"strawman CCT vs t_recfg ({len(cells)} cells, one IR pass, "
        f"backend={args.backend or 'default'}; "
        "eff = fraction of reconfig time hidden):"
    )
    k = 0
    for plan in shim.plans:
        points = "  ".join(
            f"{recfgs[r] * 1e6:.0f}us->{ccts[k + r] * 1e6:.0f}us"
            f"(eff {max(float(eff[k + r]), 0.0):.0%})"
            for r in range(len(recfgs))
        )
        log.info(f"  {plan.pattern.name:24s} {points}")
        k += len(recfgs)

    if args.bypass:
        # Topology Bypassing: re-plan the EP all-to-all with relay
        # candidates -- traffic for an uninstalled pairing rides
        # already-installed circuits at bandwidth/h instead of waiting
        # out a reconfiguration.
        ep_sizes = [
            plan.pattern.total_volume
            for plan in shim.plans
            if plan.pattern.name == "pairwise_alltoall"
        ]
        size = ep_sizes[0] if ep_sizes else 32e6
        pattern = pairwise_alltoall(fabric.n_nodes, size)
        log.info("")
        log.info(
            f"--- topology bypassing (depth {args.bypass_depth}): "
            f"pairwise all-to-all {size / 1e6:.1f}MB/node on "
            f"{fabric.n_nodes}x{fabric.n_planes} ---"
        )
        for t_recfg in recfgs:
            what_if = OpticalFabric(
                n_nodes=fabric.n_nodes,
                n_planes=fabric.n_planes,
                bandwidth=fabric.bandwidth,
                t_recfg=t_recfg,
            ).prestaged(pattern.steps[0].config)
            base = swot_greedy_chain(what_if, pattern, polish=False)
            byp = swot_greedy_chain(
                what_if, pattern, polish=False,
                bypass_depth=args.bypass_depth,
            )
            relays = sum(1 for a in byp.activities if a.route >= 0)
            log.info(
                f"  t_recfg={t_recfg * 1e6:5.0f}us: no-bypass "
                f"{base.cct * 1e6:8.1f}us  bypass {byp.cct * 1e6:8.1f}us "
                f"({1 - byp.cct / base.cct:+.1%}, {relays} relay hops)"
            )
            if t_recfg == recfgs[-1] and relays:
                log.info(byp.timeline())


if __name__ == "__main__":
    main()
