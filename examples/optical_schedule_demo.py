"""Per-iteration optical plan for a production MoE training step.

Profiles the collectives one optimizer step of qwen2-moe-a2.7b will issue
on the 16x16 production mesh (DP gradient sync, TP activation
all-reduces, EP all-to-alls), schedules each on the optical fabric with
SWOT, and prints the timelines + per-iteration optical report --
the paper's Phase 1/Phase 2 flow end to end.  Closes with a batched
what-if sweep over reconfiguration latencies through the array IR
(`repro.core.batch_evaluate`) on a selectable timing backend.

``--bypass`` appends a Topology-Bypassing section: the EP all-to-all is
re-planned with relay candidates up to ``--bypass-depth`` hops
(`repro.core.bypass`), printing the relay timeline and the CCT
reduction vs the no-bypass greedy across the ``t_recfg`` axis.

    PYTHONPATH=src python examples/optical_schedule_demo.py \
        [--backend numpy|jax|pallas] [--bypass] [--bypass-depth H]
"""

import argparse

from repro.configs.base import shape_cell
from repro.configs.registry import get_config
from repro.core import (
    OpticalFabric,
    SwotShim,
    TPU_V5E_LINK_BANDWIDTH,
    batch_evaluate,
    pairwise_alltoall,
    strawman_instance,
)
from repro.core.greedy import swot_greedy_chain
from repro.core.planner import profile_train_step
from repro.models.lm import _decoder_specs  # spec-only; no allocation
from repro.sharding.rules import MeshContext, abstract_mesh_compat


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--backend",
        choices=("numpy", "jax", "pallas"),
        default=None,
        help="IR timing backend for the what-if sweep "
        "(default: REPRO_IR_BACKEND env, else numpy)",
    )
    parser.add_argument(
        "--bypass",
        action="store_true",
        help="add the Topology-Bypassing section (relay-routing the EP "
        "all-to-all over installed circuits)",
    )
    parser.add_argument(
        "--bypass-depth",
        type=int,
        default=2,
        metavar="H",
        help="maximum relay hops for bypass candidates (default 2)",
    )
    args = parser.parse_args()
    cfg = get_config("qwen2_moe_a2_7b")
    # AbstractMesh: the planner only needs mesh *shapes*; no devices.
    mesh = abstract_mesh_compat((16, 16), ("data", "model"))
    ctx = MeshContext(mesh=mesh, dp_axes=("data",))
    specs = _decoder_specs(cfg, ctx)
    cell = shape_cell("train_4k")

    requests = profile_train_step(cfg, ctx, cell, specs)
    print(f"profiled {len(requests)} collective signatures for one "
          f"{cfg.name} train step on 16x16:")
    for r in requests:
        print(f"  {r.tag:28s} {r.algorithm:24s} n={r.n_nodes:3d} "
              f"{r.size / 1e6:10.2f} MB/node")

    # TPU-calibrated optical fabric: 16 endpoints x 4 OCS planes.
    fabric = OpticalFabric(
        n_nodes=16,
        n_planes=4,
        bandwidth=TPU_V5E_LINK_BANDWIDTH,
        t_recfg=200e-6,
    )
    shim = SwotShim(fabric)
    shim.install(requests)  # Phase 1
    for r in requests:  # Phase 2: one training iteration
        shim.intercept(r)
    print()
    print(shim.iteration_report())
    print()
    for plan in shim.plans:
        print(f"--- {plan.pattern.name} "
              f"{plan.pattern.total_volume / 1e6:.1f}MB/node ---")
        print(plan.schedule.timeline())
        print()

    # What-if sweep: how does lockstep-ICR CCT move with OCS reconfig
    # latency?  One batched array-IR pass evaluates every (collective,
    # t_recfg) cell -- no per-instance schedule objects.
    recfgs = (25e-6, 100e-6, 200e-6, 800e-6)
    cells = [
        strawman_instance(
            OpticalFabric(
                n_nodes=plan.fabric.n_nodes,
                n_planes=plan.fabric.n_planes,
                bandwidth=plan.fabric.bandwidth,
                t_recfg=t_recfg,
            ),
            plan.pattern,
            prestage=True,
        )
        for plan in shim.plans
        for t_recfg in recfgs
    ]
    ccts = batch_evaluate(cells, backend=args.backend).cct
    print(
        f"strawman CCT vs t_recfg ({len(cells)} cells, one IR pass, "
        f"backend={args.backend or 'default'}):"
    )
    k = 0
    for plan in shim.plans:
        points = "  ".join(
            f"{recfgs[r] * 1e6:.0f}us->{ccts[k + r] * 1e6:.0f}us"
            for r in range(len(recfgs))
        )
        print(f"  {plan.pattern.name:24s} {points}")
        k += len(recfgs)

    if args.bypass:
        # Topology Bypassing: re-plan the EP all-to-all with relay
        # candidates -- traffic for an uninstalled pairing rides
        # already-installed circuits at bandwidth/h instead of waiting
        # out a reconfiguration.
        ep_sizes = [
            plan.pattern.total_volume
            for plan in shim.plans
            if plan.pattern.name == "pairwise_alltoall"
        ]
        size = ep_sizes[0] if ep_sizes else 32e6
        pattern = pairwise_alltoall(fabric.n_nodes, size)
        print()
        print(
            f"--- topology bypassing (depth {args.bypass_depth}): "
            f"pairwise all-to-all {size / 1e6:.1f}MB/node on "
            f"{fabric.n_nodes}x{fabric.n_planes} ---"
        )
        for t_recfg in recfgs:
            what_if = OpticalFabric(
                n_nodes=fabric.n_nodes,
                n_planes=fabric.n_planes,
                bandwidth=fabric.bandwidth,
                t_recfg=t_recfg,
            ).prestaged(pattern.steps[0].config)
            base = swot_greedy_chain(what_if, pattern, polish=False)
            byp = swot_greedy_chain(
                what_if, pattern, polish=False,
                bypass_depth=args.bypass_depth,
            )
            relays = sum(1 for a in byp.activities if a.route >= 0)
            print(
                f"  t_recfg={t_recfg * 1e6:5.0f}us: no-bypass "
                f"{base.cct * 1e6:8.1f}us  bypass {byp.cct * 1e6:8.1f}us "
                f"({1 - byp.cct / base.cct:+.1%}, {relays} relay hops)"
            )
            if t_recfg == recfgs[-1] and relays:
                print(byp.timeline())


if __name__ == "__main__":
    main()
